//! Differential-oracle battery for batch-dynamic truss maintenance.
//!
//! For a graph from **every** `gen/` family, drive a [`DynamicTruss`]
//! through seeded random insert/delete batches and assert after every
//! single batch that the maintained trussness equals a from-scratch PKT
//! recompute on the same graph (edge ids align because both sides keep
//! the lexicographic edge order). Batches are deliberately dirty — they
//! contain duplicates, self-loops, already-present inserts and
//! already-absent removes — and the whole matrix runs at batch sizes
//! 1 / 8 / 256 across 1 / 2 / 4 threads.
//!
//! This is the test-tree face of `validate::check_dynamic`: the unit
//! tests prove the machinery catches a corrupted state, this battery
//! proves the maintenance never produces one.

use trussx::gen;
use trussx::graph::{Graph, Vertex};
use trussx::par::Pool;
use trussx::truss::{pkt, DynamicTruss};
use trussx::util::{fnv1a, Rng};

/// One representative per generator family (small enough that the
/// oracle recompute after every batch stays cheap).
fn families(seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        ("complete", gen::complete(7)),
        ("ring", gen::ring(24)),
        ("star", gen::star(16)),
        ("path", gen::path(20)),
        ("grid2d", gen::grid2d(5, 6)),
        ("er", gen::erdos_renyi(40, 0.15, seed)),
        ("ba", gen::barabasi_albert(40, 3, seed)),
        ("ws", gen::watts_strogatz(36, 4, 0.2, seed)),
        ("rmat", gen::rmat(48, 160, 0.57, 0.19, 0.19, seed)),
        ("pp", gen::planted_partition(3, 10, 0.8, 0.05, seed)),
    ]
}

/// A batch of `size` random pairs over a slightly-too-wide id range
/// (some endpoints fall outside the current vertex set: new vertices on
/// insert, guaranteed-absent edges on remove), plus guaranteed dirt —
/// a self-loop and a duplicate — regardless of rng luck.
fn random_batch(rng: &mut Rng, n: usize, size: usize) -> Vec<(Vertex, Vertex)> {
    let span = n as u64 + 4;
    let mut batch: Vec<(Vertex, Vertex)> = (0..size)
        .map(|_| (rng.below(span) as Vertex, rng.below(span) as Vertex))
        .collect();
    batch.push((1, 1));
    batch.push(batch[0]);
    batch
}

/// The oracle: maintained trussness must equal a fresh decomposition.
fn assert_oracle(dt: &DynamicTruss, pool: &Pool, fam: &str, step: usize) {
    let want = pkt(dt.eg(), pool).trussness;
    if dt.trussness() != &want[..] {
        let diverging: Vec<String> = dt
            .eg()
            .el
            .iter()
            .enumerate()
            .filter(|&(e, _)| dt.trussness()[e] != want[e])
            .map(|(e, &(u, v))| {
                format!("<{u},{v}>: maintained={} fresh={}", dt.trussness()[e], want[e])
            })
            .collect();
        panic!(
            "family={fam} step={step}: maintained trussness diverged on {} edge(s):\n{}",
            diverging.len(),
            diverging.join("\n")
        );
    }
}

/// Drive every family through `rounds` alternating update batches at
/// one (threads, batch size) point of the matrix.
fn drive(threads: usize, batch_size: usize, rounds: usize) {
    let pool = Pool::new(threads);
    let seed = fnv1a(b"dynamic-differential")
        ^ (threads as u64) << 32
        ^ (batch_size as u64);
    for (fam, g) in families(seed) {
        let mut rng = Rng::new(seed ^ fnv1a(fam.as_bytes()));
        let mut dt = DynamicTruss::new(g, threads);
        assert_oracle(&dt, &pool, fam, 0);
        for step in 1..=rounds {
            let batch = random_batch(&mut rng, dt.n(), batch_size);
            if rng.chance(0.5) {
                dt.insert_batch(&batch);
            } else {
                dt.remove_batch(&batch);
            }
            assert_oracle(&dt, &pool, fam, step);
        }
        // the deep check also recounts supports serially
        let rep = dt.validate_maintained();
        assert!(rep.ok(), "family={fam}: {}", rep.error().unwrap_or_default());
    }
}

#[test]
fn differential_threads1_batch1() {
    drive(1, 1, 4);
}

#[test]
fn differential_threads1_batch8() {
    drive(1, 8, 4);
}

#[test]
fn differential_threads1_batch256() {
    drive(1, 256, 3);
}

#[test]
fn differential_threads2_batch1() {
    drive(2, 1, 4);
}

#[test]
fn differential_threads2_batch8() {
    drive(2, 8, 4);
}

#[test]
fn differential_threads2_batch256() {
    drive(2, 256, 3);
}

#[test]
fn differential_threads4_batch1() {
    drive(4, 1, 4);
}

#[test]
fn differential_threads4_batch8() {
    drive(4, 8, 4);
}

#[test]
fn differential_threads4_batch256() {
    drive(4, 256, 3);
}

#[test]
fn differential_tear_down_and_rebuild() {
    // remove every edge in two halves, then rebuild from empty: the
    // maintenance must survive m → 0 and grow back to the exact start
    let g = gen::planted_partition(2, 8, 0.9, 0.1, 11);
    let pool = Pool::new(2);
    let mut dt = DynamicTruss::new(g, 2);
    let start = dt.trussness().to_vec();
    let all = dt.eg().el.clone();
    let half = all.len() / 2;
    dt.remove_batch(&all[..half]);
    assert_oracle(&dt, &pool, "teardown", 1);
    dt.remove_batch(&all[half..]);
    assert_eq!(dt.m(), 0);
    dt.insert_batch(&all[half..]);
    assert_oracle(&dt, &pool, "rebuild", 2);
    dt.insert_batch(&all[..half]);
    assert_oracle(&dt, &pool, "rebuild", 3);
    assert_eq!(dt.trussness(), &start[..], "round trip must restore the start state");
}

#[test]
fn differential_insert_remove_same_batch() {
    // inserting a batch and removing the identical batch must be a
    // no-op on trussness, for every family
    for (fam, g) in families(0xABCD) {
        let mut rng = Rng::new(fnv1a(fam.as_bytes()));
        let pool = Pool::new(2);
        let mut dt = DynamicTruss::new(g, 2);
        let before = dt.trussness().to_vec();
        let n = dt.n();
        let batch: Vec<(Vertex, Vertex)> = (0..8)
            .map(|_| (rng.below(n as u64) as Vertex, rng.below(n as u64) as Vertex))
            .collect();
        // only insert what was absent, then remove exactly that
        let fresh: Vec<(Vertex, Vertex)> = batch
            .iter()
            .copied()
            .filter(|&(u, v)| u != v && dt.eg().edge_id(u.min(v), u.max(v)).is_none())
            .collect();
        dt.insert_batch(&fresh);
        assert_oracle(&dt, &pool, fam, 1);
        dt.remove_batch(&fresh);
        assert_oracle(&dt, &pool, fam, 2);
        assert_eq!(dt.trussness(), &before[..], "family={fam}");
    }
}
