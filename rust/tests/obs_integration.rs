//! Integration tests for the observability subsystem: server METRICS /
//! STATUS round-trips, trace-sink capture vs `PktStats`, and the
//! span-duration accounting property.
//!
//! The trace sink and the metrics registry are process-global, so every
//! test in this binary serializes on one lock: a PKT run from a
//! concurrent test would otherwise leak `pkt.*` events into a trace
//! capture under inspection.

use std::sync::Mutex;

use trussx::coordinator::{serve, Client};
use trussx::gen;
use trussx::graph::EdgeGraph;
use trussx::obs::{report, sink};
use trussx::par::Pool;
use trussx::truss;

static OBS_TEST_LOCK: Mutex<()> = Mutex::new(());

/// Value of the first sample whose line starts with `prefix`, or 0 if
/// the metric has not been registered yet.
fn sample(body: &str, prefix: &str) -> f64 {
    body.lines()
        .find(|l| l.starts_with(prefix))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0)
}

#[test]
fn server_metrics_and_status_roundtrip() {
    let _guard = OBS_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let h = serve("127.0.0.1:0").unwrap();
    let mut c = Client::connect(h.addr).unwrap();

    // baseline (the registry is process-global, so earlier tests may
    // have counted requests already — assert monotone deltas)
    let before = c.metrics().unwrap();
    let d0 = sample(&before, "server_requests_total{verb=\"DECOMP\"}");
    let h0 = sample(&before, "server_requests_total{verb=\"HIST\"}");

    let r = c.request("DECOMP er:n=60,p=0.15,seed=1 threads=2").unwrap();
    assert!(r.starts_with("OK "), "{r}");
    let r = c.request("HIST er:n=60,p=0.15,seed=2").unwrap();
    assert!(r.starts_with("OK "), "{r}");

    let body = c.metrics().unwrap();
    assert!(body.contains("# TYPE server_requests_total counter"), "{body}");
    assert!(body.contains("# TYPE server_request_seconds histogram"), "{body}");
    let d1 = sample(&body, "server_requests_total{verb=\"DECOMP\"}");
    let h1 = sample(&body, "server_requests_total{verb=\"HIST\"}");
    assert!(d1 >= d0 + 1.0, "DECOMP count {d0} -> {d1}");
    assert!(h1 >= h0 + 1.0, "HIST count {h0} -> {h1}");
    // the jobs ran PKT, so the phase histograms must be present
    assert!(body.contains("phase_seconds_bucket{phase=\"pkt.peel\""), "{body}");
    assert!(body.contains("phase_seconds_bucket{phase=\"pkt.support\""), "{body}");
    assert!(
        sample(&body, "server_request_seconds_count{verb=\"DECOMP\"}") >= 1.0,
        "{body}"
    );

    // counters keep incrementing across further requests
    let r = c.request("DECOMP complete:n=6").unwrap();
    assert!(r.starts_with("OK "), "{r}");
    let after = c.metrics().unwrap();
    let d2 = sample(&after, "server_requests_total{verb=\"DECOMP\"}");
    assert!(d2 >= d1 + 1.0, "DECOMP count {d1} -> {d2}");

    // enriched STATUS: this server ran exactly 3 jobs, none in flight
    let status = c.request("STATUS").unwrap();
    assert!(status.starts_with("OK jobs=3 "), "{status}");
    assert!(status.contains("inflight=0"), "{status}");
    let uptime: f64 = status
        .split_whitespace()
        .find_map(|f| f.strip_prefix("uptime_secs="))
        .unwrap_or_else(|| panic!("no uptime in {status}"))
        .parse()
        .unwrap();
    assert!(uptime >= 0.0);
    h.shutdown();
}

#[test]
fn trace_matches_pkt_stats_within_one_percent() {
    let _guard = OBS_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let path = std::env::temp_dir().join("trussx_obs_acceptance.jsonl");
    let path = path.to_str().unwrap().to_string();
    sink::set_path(&path).unwrap();

    let g = gen::planted_partition(6, 20, 0.7, 0.02, 42);
    let eg = EdgeGraph::new(g);
    let pool = Pool::new(1);
    let res = truss::pkt(&eg, &pool);
    sink::disable(); // flushes

    let events = report::read_trace(&path).unwrap();
    let sum_us = |name: &str| -> f64 {
        events.iter().filter(|e| e.name == name).map(|e| e.dur_us).sum()
    };
    assert_eq!(events.iter().filter(|e| e.name == "pkt.support").count(), 1);
    assert_eq!(events.iter().filter(|e| e.name == "pkt.peel").count(), 1);
    assert_eq!(
        events.iter().filter(|e| e.name == "pkt.level").count() as u32,
        res.stats.levels,
        "one pkt.level event per peeling level"
    );

    // acceptance: trace-derived total within 1% of PktStats.total_secs
    let trace_total = (sum_us("pkt.support") + sum_us("pkt.peel")) * 1e-6;
    let diff = (trace_total - res.stats.total_secs).abs();
    assert!(
        diff <= res.stats.total_secs * 0.01,
        "trace total {trace_total}s vs stats total {}s",
        res.stats.total_secs
    );

    // `pallas report` renders the same totals from the capture
    let rendered = report::render_trace_report(&path).unwrap();
    assert!(rendered.contains("phase summary"), "{rendered}");
    assert!(rendered.contains("pkt levels"), "{rendered}");
    let report_total: f64 = rendered
        .lines()
        .find(|l| l.starts_with("totals:"))
        .and_then(|l| l.split_whitespace().find_map(|f| f.strip_prefix("total=")))
        .and_then(|v| v.strip_suffix('s'))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no totals line in {rendered}"));
    let diff = (report_total - res.stats.total_secs).abs();
    assert!(
        diff <= res.stats.total_secs * 0.01,
        "report total {report_total}s vs stats total {}s",
        res.stats.total_secs
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn span_phase_durations_account_for_total() {
    let _guard = OBS_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // forall generated graphs: the span-derived phase times nest
    // consistently — per-level spans tile the peel, scan+process fit
    // inside the levels, and support+levels accounts for the total.
    let cases = vec![
        gen::planted_partition(5, 18, 0.65, 0.03, 1),
        gen::planted_partition(3, 30, 0.5, 0.05, 2),
        gen::erdos_renyi(150, 0.08, 3),
        gen::barabasi_albert(200, 6, 4),
        gen::complete(24),
    ];
    for (i, g) in cases.into_iter().enumerate() {
        let eg = EdgeGraph::new(g);
        let pool = Pool::new(2);
        let st = truss::pkt(&eg, &pool).stats;
        let eps = 1e-3;
        assert!(st.support_secs > 0.0, "case {i}: {st:?}");
        assert!(st.total_secs >= st.support_secs, "case {i}: {st:?}");
        // scan and process spans are nested inside level spans
        assert!(st.scan_secs + st.process_secs <= st.levels_secs + eps, "case {i}: {st:?}");
        // nonzero levels are a subset of all levels
        let per_level_sum: f64 = st.per_level.iter().map(|l| l.secs).sum();
        assert!(per_level_sum <= st.levels_secs + eps, "case {i}: {st:?}");
        // support + levels ≈ total (level spans tile the peel loop)
        let accounted = st.support_secs + st.levels_secs;
        assert!(accounted <= st.total_secs * 1.05 + eps, "case {i}: {st:?}");
        assert!(accounted >= st.total_secs * 0.5 - eps, "case {i}: {st:?}");
    }
}
