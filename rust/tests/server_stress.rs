//! Executor stress tests: admission control under saturation, per-job
//! deadlines, panic isolation, graceful drain, oversized-line defense,
//! and client retry. Fault injection goes through
//! [`ExecutorConfig::fault`] directly — never the `TRUSSX_FAULT` env
//! var, which would race across the parallel test harness.
//!
//! The metrics registry is process-global and shared with every other
//! test in the process, so counter assertions are monotone deltas
//! (`after >= before + k`), never exact values.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use trussx::coordinator::{serve_with, Client, ExecutorConfig, FaultSpec, ServerConfig};
use trussx::obs;

/// A server whose executor is saturated by design: `workers` workers,
/// `queue` queue slots, every job delayed `delay_ms` at `job.start`.
fn slow_server(workers: usize, queue: usize, delay_ms: u64, drain: Duration) -> ServerConfig {
    ServerConfig {
        executor: ExecutorConfig {
            workers,
            queue_depth: queue,
            job_timeout: None,
            fault: Some(
                FaultSpec::parse(&format!("job.start:{delay_ms}")).expect("valid fault spec"),
            ),
        },
        drain,
    }
}

fn counter(name: &str) -> u64 {
    obs::global().counter(name, &[]).get()
}

/// Saturation: pool=1, queue=1, 8 clients firing at once through a
/// barrier. Some must succeed, some must be refused with a structured
/// BUSY carrying a usable retry hint — and nothing may hang.
#[test]
fn saturation_rejects_with_busy() {
    let rejected_before = counter("server_rejected_total");
    let h = serve_with("127.0.0.1:0", slow_server(1, 1, 200, Duration::from_secs(10))).unwrap();
    let addr = h.addr;
    let barrier = Arc::new(Barrier::new(8));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let b = barrier.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                b.wait();
                c.request("DECOMP complete:n=5 threads=1").unwrap()
            })
        })
        .collect();
    let replies: Vec<String> = handles.into_iter().map(|t| t.join().unwrap()).collect();

    let ok = replies.iter().filter(|r| r.starts_with("OK ")).count();
    let busy = replies.iter().filter(|r| r.starts_with("ERR BUSY ")).count();
    assert_eq!(ok + busy, 8, "every reply is OK or BUSY: {replies:?}");
    assert!(ok >= 1, "the worker must serve someone: {replies:?}");
    assert!(busy >= 1, "8 clients vs 1 worker + 1 slot must refuse someone: {replies:?}");
    for r in replies.iter().filter(|r| r.starts_with("ERR BUSY ")) {
        let hint: u64 = r
            .split_whitespace()
            .find_map(|f| f.strip_prefix("retry_after_ms="))
            .expect("BUSY carries retry_after_ms")
            .parse()
            .expect("numeric hint");
        assert!((10..=5000).contains(&hint), "hint in clamp range: {r}");
    }
    assert!(
        counter("server_rejected_total") >= rejected_before + busy as u64,
        "rejections must be counted"
    );
    h.shutdown();
}

/// A `timeout=` that expires inside the fault delay returns a
/// structured DEADLINE promptly, and the worker survives to serve the
/// same connection again.
#[test]
fn deadline_frees_the_worker() {
    let timeouts_before = counter("server_timeouts_total");
    let h = serve_with("127.0.0.1:0", slow_server(1, 4, 300, Duration::from_secs(10))).unwrap();
    let mut c = Client::connect(h.addr).unwrap();
    let t0 = Instant::now();
    let r = c.request("DECOMP complete:n=5 threads=1 timeout=0.03").unwrap();
    assert!(r.starts_with("ERR DEADLINE "), "{r}");
    assert!(t0.elapsed() < Duration::from_secs(5), "deadline must cut the 300ms job short");
    assert!(counter("server_timeouts_total") >= timeouts_before + 1);
    // same connection, same single worker: it must still answer
    let r = c.request("DECOMP complete:n=5 threads=1").unwrap();
    assert!(r.starts_with("OK "), "worker must be reclaimed: {r}");
    h.shutdown();
}

/// A deadline expiring mid-peel (no fault injection — the decomposition
/// itself is the slow part) unwinds at a level boundary with partial
/// progress in the reply.
#[test]
fn deadline_interrupts_a_real_peel() {
    let cfg = ServerConfig {
        executor: ExecutorConfig { workers: 1, queue_depth: 4, job_timeout: None, fault: None },
        drain: Duration::from_secs(10),
    };
    let h = serve_with("127.0.0.1:0", cfg).unwrap();
    let mut c = Client::connect(h.addr).unwrap();
    // large enough that support+peel far exceeds 1ms even in debug
    // builds; the deadline fires at the first boundary it is seen at
    let t0 = Instant::now();
    let r = c
        .request("DECOMP er:n=4000,p=0.01,seed=7 threads=2 timeout=0.001")
        .unwrap();
    assert!(r.starts_with("ERR DEADLINE "), "{r}");
    assert!(r.contains("job stopped at "), "partial progress in the reply: {r}");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "cancellation latency is one boundary, not the full job"
    );
    h.shutdown();
}

/// An injected panic is isolated to the job: the client gets a
/// structured internal error and the single worker keeps serving.
#[test]
fn panic_is_contained() {
    let cfg = ServerConfig {
        executor: ExecutorConfig {
            workers: 1,
            queue_depth: 4,
            job_timeout: None,
            fault: Some(FaultSpec::parse("job.start:panic").unwrap()),
        },
        drain: Duration::from_secs(10),
    };
    let h = serve_with("127.0.0.1:0", cfg).unwrap();
    let mut c = Client::connect(h.addr).unwrap();
    let r = c.request("DECOMP complete:n=5 threads=1").unwrap();
    assert!(r.starts_with("ERR ") && r.contains("panicked"), "{r}");
    // the worker survived the panic: a second job gets an answer (it
    // panics too — the point is that a reply arrives at all)
    let r2 = c.request("DECOMP complete:n=5 threads=1").unwrap();
    assert!(r2.starts_with("ERR ") && r2.contains("panicked"), "{r2}");
    // and the connection + non-job verbs still work
    let status = c.request("STATUS").unwrap();
    assert!(status.starts_with("OK "), "{status}");
    assert!(status.contains("inflight=0"), "RAII guard must release on panic: {status}");
    h.shutdown();
}

/// Shutdown with a generous drain budget waits for the in-flight job:
/// the client sees a success, not a cancellation.
#[test]
fn shutdown_drains_inflight() {
    let h = serve_with("127.0.0.1:0", slow_server(1, 4, 150, Duration::from_secs(10))).unwrap();
    let addr = h.addr;
    let client = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.request("DECOMP complete:n=5 threads=1").unwrap()
    });
    // give the request time to reach the executor before draining (a
    // late submit would see ERR SHUTDOWN instead of being drained)
    std::thread::sleep(Duration::from_millis(100));
    h.shutdown();
    let reply = client.join().unwrap();
    assert!(reply.starts_with("OK "), "drain must let the job finish: {reply}");
}

/// Shutdown whose drain deadline expires cancels the straggler through
/// its token: shutdown returns fast and the client sees CANCELLED.
#[test]
fn shutdown_deadline_cancels_stragglers() {
    let cancelled_before = counter("server_cancelled_total");
    let h =
        serve_with("127.0.0.1:0", slow_server(1, 4, 10_000, Duration::from_millis(150))).unwrap();
    let addr = h.addr;
    let client = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.request("DECOMP complete:n=5 threads=1").unwrap()
    });
    // let the request reach the executor before the drain begins
    std::thread::sleep(Duration::from_millis(100));
    let t0 = Instant::now();
    h.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown must not wait out a 10s job past its 150ms drain budget"
    );
    let reply = client.join().unwrap();
    assert!(reply.starts_with("ERR CANCELLED "), "{reply}");
    assert!(counter("server_cancelled_total") >= cancelled_before + 1);
}

/// A request line past the 64 KiB cap is refused with a structured
/// error — without reading it into memory — and the connection remains
/// fully usable afterwards.
#[test]
fn oversized_line_is_rejected_not_fatal() {
    let h = serve_with(
        "127.0.0.1:0",
        ServerConfig {
            executor: ExecutorConfig {
                workers: 1,
                queue_depth: 4,
                job_timeout: None,
                fault: None,
            },
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut c = Client::connect(h.addr).unwrap();
    let huge = format!("DECOMP {}", "x".repeat(100 * 1024));
    let r = c.request(&huge).unwrap();
    assert!(r.starts_with("ERR line too long"), "{r}");
    // the same connection still serves real requests
    let r = c.request("STATUS").unwrap();
    assert!(r.starts_with("OK "), "{r}");
    let r = c.request("DECOMP complete:n=5 threads=1").unwrap();
    assert!(r.starts_with("OK "), "{r}");
    // a line of exactly-cap length terminated by its newline is fine
    // (the guard triggers on truncation, not on size alone)
    let exact = format!("STATUS{}", " ".repeat(64 * 1024 - "STATUS".len() - 1));
    assert_eq!(exact.len(), 64 * 1024 - 1); // +1 for the newline = cap
    let r = c.request(&exact).unwrap();
    assert!(r.starts_with("OK "), "{r}");
    h.shutdown();
}

/// `request_with_retry` rides out BUSY refusals with backoff + jitter:
/// all clients eventually get served against a saturated executor.
#[test]
fn client_retry_wins_through_saturation() {
    let h = serve_with("127.0.0.1:0", slow_server(1, 1, 50, Duration::from_secs(10))).unwrap();
    let addr = h.addr;
    let barrier = Arc::new(Barrier::new(4));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let b = barrier.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                b.wait();
                c.request_with_retry("DECOMP complete:n=5 threads=1", 20).unwrap()
            })
        })
        .collect();
    for t in handles {
        let reply = t.join().unwrap();
        assert!(reply.starts_with("OK "), "retries must converge: {reply}");
    }
    assert_eq!(h.jobs_served(), 4);
    h.shutdown();
}
