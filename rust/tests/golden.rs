//! Golden decompositions: small, hand-checkable fixtures whose exact
//! per-edge trussness is locked down — both the full run and the state
//! after dynamic updates. A regression anywhere in the support/peel/
//! maintenance stack shows up here as a concrete edge with a concrete
//! wrong number, not as a property-test shrink hunt.
//!
//! Fixture 1 — the paper's Figure 1 shape: two triangles joined by
//! bridge edges. Every edge's trussness is checkable by eye (triangle
//! edges are in one triangle each → 3; bridges close none → 2).
//!
//! Fixture 2 — a planted clique: K6 dangling off a path. The clique is
//! a 6-truss (every edge has the 4 other clique vertices as common
//! neighbors), everything else is triangle-free → 2.

use trussx::graph::{EdgeGraph, GraphBuilder, Vertex};
use trussx::par::Pool;
use trussx::truss::{class_histogram, ktruss_components, pkt, wc, DynamicTruss};

/// Assert the decomposition of `edges` equals `expect` edge-for-edge
/// (expect is in lexicographic edge order, like `EdgeGraph::el`), under
/// both the parallel (pkt) and the serial reference (wc) algorithms.
fn assert_golden(edges: &[(Vertex, Vertex)], expect: &[((Vertex, Vertex), u32)]) {
    let g = GraphBuilder::new().edges_vec(edges.to_vec()).build();
    let eg = EdgeGraph::new(g);
    assert_eq!(eg.m(), expect.len(), "fixture edge count");
    for res in [pkt(&eg, &Pool::new(2)).trussness, wc(&eg).trussness] {
        for (e, &(uv, want)) in expect.iter().enumerate() {
            assert_eq!(eg.el[e], uv, "edge order drifted at id {e}");
            assert_eq!(
                res[e], want,
                "edge <{},{}> has trussness {} (golden: {want})",
                uv.0, uv.1, res[e]
            );
        }
    }
}

/// Figure 1 shape: triangles {0,1,2} and {3,4,5}, bridges (2,3), (0,4).
fn figure1_edges() -> Vec<(Vertex, Vertex)> {
    vec![(0, 1), (0, 2), (1, 2), (3, 4), (3, 5), (4, 5), (2, 3), (0, 4)]
}

#[test]
fn golden_figure1_full() {
    assert_golden(
        &figure1_edges(),
        &[
            ((0, 1), 3),
            ((0, 2), 3),
            ((0, 4), 2),
            ((1, 2), 3),
            ((2, 3), 2),
            ((3, 4), 3),
            ((3, 5), 3),
            ((4, 5), 3),
        ],
    );
    // structure: exactly two 3-truss components (the two triangles),
    // one connected 2-truss (everything), no 4-truss
    let g = GraphBuilder::new().edges_vec(figure1_edges()).build();
    let eg = EdgeGraph::new(g);
    let t = pkt(&eg, &Pool::new(1)).trussness;
    assert_eq!(class_histogram(&t), vec![0, 0, 2, 6]);
    assert_eq!(ktruss_components(&eg, &t, 3).len(), 2);
    assert_eq!(ktruss_components(&eg, &t, 2).len(), 1);
    assert!(ktruss_components(&eg, &t, 4).is_empty());
}

#[test]
fn golden_figure1_after_updates() {
    let g = GraphBuilder::new().edges_vec(figure1_edges()).build();
    let mut dt = DynamicTruss::new(g, 2);

    // insert (2,4): closes triangles {0,2,4} and {2,3,4}, welding the
    // two triangles into one component where every edge sits in at
    // least one triangle → the whole graph becomes a single 3-truss
    let r = dt.insert_batch(&[(2, 4)]);
    assert_eq!((r.applied, r.t_max, r.m), (1, 3, 9));
    let expect3: &[((Vertex, Vertex), u32)] = &[
        ((0, 1), 3),
        ((0, 2), 3),
        ((0, 4), 3),
        ((1, 2), 3),
        ((2, 3), 3),
        ((2, 4), 3),
        ((3, 4), 3),
        ((3, 5), 3),
        ((4, 5), 3),
    ];
    for (e, &(uv, want)) in expect3.iter().enumerate() {
        assert_eq!(dt.eg().el[e], uv);
        assert_eq!(dt.trussness()[e], want, "edge <{},{}>", uv.0, uv.1);
    }

    // remove the two shared spines (0,2) and (3,4): every remaining
    // triangle loses an edge, so the graph is triangle-free → all 2
    let r = dt.remove_batch(&[(0, 2), (3, 4)]);
    assert_eq!((r.applied, r.t_max, r.m), (2, 2, 7));
    assert!(dt.trussness().iter().all(|&t| t == 2), "{:?}", dt.trussness());
    assert!(dt.validate_maintained().ok());
}

/// Planted clique: K6 on 0..=5, path on 6..=15, connector (5,6).
fn planted_clique_edges() -> Vec<(Vertex, Vertex)> {
    let mut edges = vec![];
    for u in 0..6u32 {
        for v in (u + 1)..6 {
            edges.push((u, v));
        }
    }
    for i in 6..15u32 {
        edges.push((i, i + 1));
    }
    edges.push((5, 6));
    edges
}

#[test]
fn golden_planted_clique_full() {
    let g = GraphBuilder::new().edges_vec(planted_clique_edges()).build();
    let eg = EdgeGraph::new(g);
    for t in [pkt(&eg, &Pool::new(2)).trussness, wc(&eg).trussness] {
        // 15 clique edges at 6, the 9 path edges + connector at 2
        assert_eq!(class_histogram(&t), vec![0, 0, 10, 0, 0, 0, 15]);
        for (e, &(u, v)) in eg.el.iter().enumerate() {
            let want = if v < 6 { 6 } else { 2 };
            assert_eq!(t[e], want, "edge <{u},{v}>");
        }
    }
    let t = pkt(&eg, &Pool::new(2)).trussness;
    // the 6-truss is exactly the planted clique, one component
    let comps = ktruss_components(&eg, &t, 6);
    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].len(), 15);
}

#[test]
fn golden_planted_clique_after_updates() {
    let g = GraphBuilder::new().edges_vec(planted_clique_edges()).build();
    let mut dt = DynamicTruss::new(g, 2);

    // remove one clique edge: K6 minus an edge is a 5-truss (edges at
    // the gap keep 3 common neighbors, inner edges keep 4), path stays 2
    let r = dt.remove_batch(&[(0, 1)]);
    assert_eq!((r.applied, r.t_max), (1, 5));
    assert_eq!(class_histogram(dt.trussness()), vec![0, 0, 10, 0, 0, 14]);

    // reinsert it: the exact full-graph golden state must come back
    let r = dt.insert_batch(&[(0, 1)]);
    assert_eq!((r.applied, r.t_max), (1, 6));
    assert_eq!(class_histogram(dt.trussness()), vec![0, 0, 10, 0, 0, 0, 15]);
    for (e, &(u, v)) in dt.eg().el.iter().enumerate() {
        let want = if v < 6 { 6 } else { 2 };
        assert_eq!(dt.trussness()[e], want, "edge <{u},{v}>");
    }
    assert!(dt.validate_maintained().ok());
}
