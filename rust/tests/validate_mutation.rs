//! Mutation tests for the `validate` layer: deliberately corrupt each
//! data structure the way a real concurrency bug would (a lost support
//! decrement, a broken compaction remap, an unsorted rebuild, an
//! inflated truss number) and assert the validator catches it with a
//! path precise enough to debug from.

use trussx::graph::{compact_edges, EdgeGraph};
use trussx::par::Pool;
use trussx::validate::{
    check_compaction, check_graph, check_support, check_trussness, recount_support, Report,
};
use trussx::{gen, truss};

fn sample_eg() -> EdgeGraph {
    EdgeGraph::new(gen::planted_partition(3, 8, 0.9, 0.05, 11))
}

#[test]
fn flipped_support_count_is_caught() {
    let eg = sample_eg();
    let mut s = recount_support(&eg);
    // a racing decrement that hit the wrong edge: off by one, one slot
    let victim = s.len() / 2;
    s[victim] += 1;
    let mut rep = Report::new();
    check_support(&eg, &s, &mut rep);
    assert!(!rep.ok());
    let v = &rep.violations[0];
    assert_eq!(v.check, "support.recount");
    let (u, vtx) = eg.el[victim];
    assert!(
        v.path.contains(&format!("<{u},{vtx}>")),
        "path names the corrupt edge: {v}"
    );
}

#[test]
fn broken_compaction_bijectivity_is_caught() {
    let eg = sample_eg();
    let pool = Pool::new(2);
    // keep roughly half the edges alive, as a peel stage would
    let alive = |e: u32| e % 2 == 0;
    let mut comp = compact_edges(&eg, &pool, alive);
    let mut rep = Report::new();
    check_compaction(&eg, &comp, alive, &mut rep);
    assert!(rep.ok(), "clean compaction must pass: {:?}", rep.violations);

    // duplicate one map entry: an alive edge vanishes and another is
    // mapped twice — exactly what a racy rebuild cursor produces
    let lost_old = comp.old_of_new[1] as usize;
    comp.old_of_new[1] = comp.old_of_new[0];
    let mut rep = Report::new();
    check_compaction(&eg, &comp, alive, &mut rep);
    assert!(!rep.ok());
    assert!(
        rep.violations.iter().any(|v| v.check == "compaction.bijection"),
        "{:?}",
        rep.violations
    );
    assert!(
        rep.violations.iter().any(|v| v.check == "compaction.monotone"),
        "{:?}",
        rep.violations
    );
    let (u, v) = eg.el[lost_old];
    assert!(
        rep.violations
            .iter()
            .any(|x| x.check == "compaction.bijection" && x.path.contains(&format!("<{u},{v}>"))),
        "path names the lost edge: {:?}",
        rep.violations
    );
}

#[test]
fn unsorted_adjacency_row_is_caught() {
    let mut g = gen::complete(5);
    // row 0 is [1,2,3,4]; swap the first two entries
    g.adj.swap(0, 1);
    let mut rep = Report::new();
    check_graph(&g, &mut rep);
    assert!(!rep.ok());
    let v = rep
        .violations
        .iter()
        .find(|v| v.check == "csr.sorted")
        .expect("csr.sorted fires");
    assert!(v.path.contains("u=0"), "path names the row: {v}");
}

#[test]
fn inflated_trussness_is_caught() {
    let eg = sample_eg();
    let pool = Pool::new(2);
    let mut t = truss::pkt(&eg, &pool).trussness;
    let mut rep = Report::new();
    check_trussness(&eg, &t, &mut rep);
    assert!(rep.ok(), "real output must pass: {:?}", rep.violations);
    // claim a trussness above every analytic bound
    t[0] = u32::try_from(eg.n()).unwrap() + 10;
    let mut rep = Report::new();
    check_trussness(&eg, &t, &mut rep);
    assert!(!rep.ok());
    assert!(
        rep.violations
            .iter()
            .any(|v| v.check == "truss.support_bound" || v.check == "truss.kcore_bound"),
        "{:?}",
        rep.violations
    );
}

#[test]
fn corruption_increments_failure_metric() {
    let c = trussx::obs::global().counter("validate_failures_total", &[]);
    let before = c.get();
    let eg = sample_eg();
    let mut s = recount_support(&eg);
    s[0] ^= 1;
    let mut rep = Report::new();
    check_support(&eg, &s, &mut rep);
    assert!(!rep.ok());
    assert!(c.get() > before, "validate_failures_total must move");
}
