//! Property-based tests over randomized graphs (own harness — see
//! `trussx::util::forall`): the decomposition invariants from the
//! k-truss literature, checked against all algorithm implementations.

use trussx::gen;
use trussx::graph::{EdgeGraph, GraphBuilder, Vertex};
use trussx::kcore;
use trussx::par::Pool;
use trussx::triangle;
use trussx::truss;
use trussx::util::{forall, Rng};

/// Random graph from a family chosen by the case seed — mixes degree
/// skews and clustering levels so properties see diverse structure.
fn random_graph(rng: &mut Rng) -> trussx::graph::Graph {
    match rng.below(4) {
        0 => gen::erdos_renyi(rng.range(4, 80), rng.f64() * 0.3, rng.next_u64()),
        1 => gen::rmat(rng.range(8, 128), rng.range(16, 400), 0.57, 0.19, 0.19, rng.next_u64()),
        2 => {
            let blocks = rng.range(1, 5);
            let size = rng.range(3, 14);
            gen::planted_partition(blocks, size, 0.5 + rng.f64() * 0.5, 0.05, rng.next_u64())
        }
        _ => gen::barabasi_albert(rng.range(6, 80), rng.range(1, 5), rng.next_u64()),
    }
}

#[test]
fn prop_trussness_bounds() {
    forall("trussness-bounds", 40, |rng| {
        let g = random_graph(rng);
        let eg = EdgeGraph::new(g);
        let s0 = triangle::support_naive(&eg);
        let res = truss::pkt(&eg, &Pool::new(2));
        for e in 0..eg.m() {
            let t = res.trussness[e];
            // 2 <= t(e) <= S0(e) + 2 (initial support is an upper bound)
            assert!(t >= 2);
            assert!(t <= s0[e] + 2, "edge {e}: t={t} S0={}", s0[e]);
        }
    });
}

#[test]
fn prop_truss_core_containment() {
    forall("truss-core-containment", 40, |rng| {
        let g = random_graph(rng);
        let core = kcore::bz(&g);
        let eg = EdgeGraph::new(g);
        let res = truss::pkt(&eg, &Pool::new(2));
        // k-truss edges live in the (k-1)-core
        for (e, &(u, v)) in eg.el.iter().enumerate() {
            let t = res.trussness[e];
            assert!(core[u as usize] >= t - 1, "u coreness");
            assert!(core[v as usize] >= t - 1, "v coreness");
        }
    });
}

#[test]
fn prop_edge_addition_monotone() {
    // adding an edge never decreases any existing edge's trussness
    forall("edge-addition-monotone", 25, |rng| {
        let g = random_graph(rng);
        if g.n() < 3 {
            return;
        }
        let eg = EdgeGraph::new(g.clone());
        let before = truss::pkt(&eg, &Pool::new(1)).trussness;
        // pick a non-edge
        let n = g.n();
        let mut extra = None;
        for _ in 0..64 {
            let u = rng.below(n as u64) as Vertex;
            let v = rng.below(n as u64) as Vertex;
            if u != v && !g.has_edge(u, v) {
                extra = Some((u, v));
                break;
            }
        }
        let Some((u, v)) = extra else { return };
        let mut edges: Vec<(Vertex, Vertex)> = eg.el.clone();
        edges.push((u.min(v), u.max(v)));
        let g2 = GraphBuilder::new().num_vertices(n).edges_vec(edges).build();
        let eg2 = EdgeGraph::new(g2);
        let after = truss::pkt(&eg2, &Pool::new(1)).trussness;
        for (e, &(a, b)) in eg.el.iter().enumerate() {
            let e2 = eg2.edge_id(a, b).unwrap() as usize;
            assert!(
                after[e2] >= before[e],
                "edge <{a},{b}> dropped from {} to {}",
                before[e],
                after[e2]
            );
        }
    });
}

#[test]
fn prop_relabel_invariance() {
    forall("relabel-invariance", 25, |rng| {
        let g = random_graph(rng);
        let n = g.n();
        if n == 0 {
            return;
        }
        // random permutation
        let mut perm: Vec<Vertex> = (0..n as Vertex).collect();
        rng.shuffle(&mut perm);
        let g2 = trussx::order::relabel(&g, &perm);
        let eg = EdgeGraph::new(g);
        let eg2 = EdgeGraph::new(g2);
        let t1 = truss::pkt(&eg, &Pool::new(2)).trussness;
        let t2 = truss::pkt(&eg2, &Pool::new(2)).trussness;
        for (e, &(u, v)) in eg.el.iter().enumerate() {
            let e2 = eg2
                .edge_id(perm[u as usize], perm[v as usize])
                .expect("edge preserved") as usize;
            assert_eq!(t1[e], t2[e2]);
        }
    });
}

#[test]
fn prop_support_sum_is_3x_triangles() {
    forall("support-triple-count", 40, |rng| {
        let g = random_graph(rng);
        let tri = triangle::count_triangles(&g);
        let eg = EdgeGraph::new(g);
        let s = triangle::into_plain(triangle::support_am4(&eg, &Pool::new(2)));
        assert_eq!(s.iter().map(|&x| x as u64).sum::<u64>(), 3 * tri);
    });
}

#[test]
fn prop_kclass_histogram_conserved_across_algorithms() {
    forall("kclass-conservation", 20, |rng| {
        let g = random_graph(rng);
        let eg = EdgeGraph::new(g);
        let p = truss::pkt(&eg, &Pool::new(2)).trussness;
        let w = truss::wc(&eg).trussness;
        assert_eq!(truss::class_histogram(&p), truss::class_histogram(&w));
        assert_eq!(p, w);
    });
}

/// Trussness per edge derived from the Cohen peeling reference: the
/// largest k whose k-truss still contains the edge (every edge of a
/// non-empty graph is in the 2-truss).
fn cohen_trussness(eg: &EdgeGraph) -> Vec<u32> {
    let mut t = vec![2u32; eg.m()];
    let mut k = 3u32;
    loop {
        let comps = truss::cohen_ktruss(eg, k);
        let mut any = false;
        for comp in &comps {
            for &(u, v) in comp {
                let e = eg.edge_id(u, v).expect("cohen returns real edges") as usize;
                t[e] = k;
                any = true;
            }
        }
        if !any {
            return t;
        }
        k += 1;
    }
}

#[test]
fn prop_all_algorithms_agree() {
    // pkt (parallel peel), wc (serial hash peel), ros (hash-free peel)
    // and the Cohen by-k reference must produce identical trussness on
    // every random graph; a divergence is reported as the minimized
    // list of disagreeing edges, not a blob of two arrays
    forall("algo-agreement", 15, |rng| {
        let g = random_graph(rng);
        let eg = EdgeGraph::new(g);
        let p = truss::pkt(&eg, &Pool::new(2)).trussness;
        let w = truss::wc(&eg).trussness;
        let r = truss::ros(&eg, &Pool::new(2)).trussness;
        let c = cohen_trussness(&eg);
        for (name, other) in [("wc", &w), ("ros", &r), ("cohen", &c)] {
            if &p == other {
                continue;
            }
            let diverging: Vec<String> = eg
                .el
                .iter()
                .enumerate()
                .filter(|&(e, _)| p[e] != other[e])
                .map(|(e, &(u, v))| format!("<{u},{v}>: pkt={} {name}={}", p[e], other[e]))
                .collect();
            panic!(
                "pkt vs {name} diverge on {} of {} edges:\n{}",
                diverging.len(),
                eg.m(),
                diverging.join("\n")
            );
        }
    });
}

#[test]
fn prop_definition_soundness() {
    // PKT output satisfies the definitional support bound in every
    // k-truss subgraph (expensive oracle — fewer cases)
    forall("definition-soundness", 8, |rng| {
        let g = random_graph(rng);
        let eg = EdgeGraph::new(g);
        let res = truss::pkt(&eg, &Pool::new(2));
        truss::verify_definition(&eg, &res.trussness).unwrap();
    });
}

#[test]
fn prop_compaction_agrees() {
    // the compacted/bitset peel is an optimization, not an algorithm
    // change: every (threshold, flag-repr, threads) combination must
    // reproduce the plain peel's trussness edge-for-edge
    forall("compaction-agrees", 12, |rng| {
        let g = random_graph(rng);
        let eg = EdgeGraph::new(g);
        let plain = truss::PktConfig { compact_threshold: 0.0, use_bitsets: false };
        let base = truss::pkt_config(&eg, &Pool::new(1), &plain).trussness;
        for thr in [0.0, 0.3, 1.0] {
            for bits in [false, true] {
                let cfg = truss::PktConfig { compact_threshold: thr, use_bitsets: bits };
                for threads in [1, 3] {
                    let r = truss::pkt_config(&eg, &Pool::new(threads), &cfg);
                    assert_eq!(
                        r.trussness, base,
                        "thr={thr} bits={bits} threads={threads}"
                    );
                    if thr == 0.0 {
                        assert_eq!(r.stats.rebuilds, 0, "thr=0 must never rebuild");
                    }
                }
            }
        }
    });
}

#[test]
fn prop_coreness_vs_degree_and_truss_relations() {
    forall("core-deg-truss", 30, |rng| {
        let g = random_graph(rng);
        let core = kcore::bz(&g);
        let par = kcore::park(&g, &Pool::new(3));
        assert_eq!(core, par);
        for u in 0..g.n() {
            assert!(core[u] as usize <= g.degree(u as Vertex));
        }
    });
}
