//! Integration tests across the AOT bridge: Rust loads the HLO-text
//! artifacts produced by `make artifacts` and checks the numerics
//! against both the graph algorithms (PKT) and hand-computed values.
//!
//! These tests SKIP (not fail) when artifacts/ is missing, so plain
//! `cargo test` works before `make artifacts`; `make test` always
//! builds artifacts first.

use trussx::gen;
use trussx::graph::EdgeGraph;
use trussx::par::Pool;
use trussx::runtime::{artifacts_dir, literal_matrix, literal_scalar, Runtime};
use trussx::triangle;
use trussx::truss::{self, dense::DenseBackend};

fn runtime_or_skip() -> Option<(Runtime, trussx::runtime::Manifest)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    let mut rt = Runtime::cpu().expect("PJRT CPU client");
    let manifest = rt.load_manifest(&dir).expect("load artifacts");
    Some((rt, manifest))
}

#[test]
fn artifacts_load_and_register() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    assert!(!manifest.support_blocks().is_empty());
    for b in manifest.support_blocks() {
        assert!(rt.has(&format!("support_{b}")));
        assert!(rt.has(&format!("peel_{b}")));
    }
}

#[test]
fn support_artifact_k4_numerics() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let b = manifest.support_blocks()[0];
    // K4 embedded in a b×b block: every edge in 2 triangles
    let mut a = vec![0f32; b * b];
    for u in 0..4 {
        for v in 0..4 {
            if u != v {
                a[u * b + v] = 1.0;
            }
        }
    }
    let out = rt
        .execute_f32(&format!("support_{b}"), &[literal_matrix(&a, b, b).unwrap()])
        .unwrap();
    let s = &out[0];
    for u in 0..4 {
        for v in 0..4 {
            let want = if u == v { 0.0 } else { 2.0 };
            assert_eq!(s[u * b + v], want, "S[{u},{v}]");
        }
    }
    // everything outside the embedded K4 stays zero
    assert_eq!(s.iter().sum::<f32>(), 12.0 * 2.0);
}

#[test]
fn peel_artifact_threshold_semantics() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let b = manifest.support_blocks()[0];
    // triangle + pendant edge: pendant has support 0, triangle edges 1
    let mut a = vec![0f32; b * b];
    for (u, v) in [(0, 1), (1, 2), (0, 2), (2, 3)] {
        a[u * b + v] = 1.0;
        a[v * b + u] = 1.0;
    }
    let out = rt
        .execute_f32(
            &format!("peel_{b}"),
            &[literal_matrix(&a, b, b).unwrap(), literal_scalar(1.0)],
        )
        .unwrap();
    let (a_new, s) = (&out[0], &out[1]);
    assert_eq!(a_new[2 * b + 3], 0.0, "pendant edge dropped");
    assert_eq!(a_new[3 * b + 2], 0.0);
    assert_eq!(a_new[b + 2], 1.0, "triangle edge kept");
    assert_eq!(s[b + 2], 1.0, "support output exposed");
}

#[test]
fn dense_backend_support_matches_am4() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let g = gen::erdos_renyi(60, 0.15, 17);
    let eg = EdgeGraph::new(g);
    let backend = DenseBackend::for_graph(&rt, &manifest, eg.n()).unwrap();
    let xla_s = backend.support(&eg).unwrap();
    let am4_s = triangle::into_plain(triangle::support_am4(&eg, &Pool::new(2)));
    assert_eq!(xla_s, am4_s);
}

#[test]
fn dense_backend_decompose_matches_pkt() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let cases = vec![
        gen::complete(12),
        gen::erdos_renyi(50, 0.2, 3),
        gen::planted_partition(2, 20, 0.8, 0.05, 4),
        gen::ring(24),
    ];
    for g in cases {
        let eg = EdgeGraph::new(g);
        let backend = DenseBackend::for_graph(&rt, &manifest, eg.n()).unwrap();
        let xla_truss = backend.decompose(&eg).unwrap();
        let pkt_truss = truss::pkt(&eg, &Pool::new(2)).trussness;
        assert_eq!(xla_truss, pkt_truss, "n={}", eg.n());
    }
}

#[test]
fn dense_backend_rejects_oversized_graph() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let max_b = *manifest.support_blocks().last().unwrap();
    let g = gen::ring(max_b + 1);
    let eg = EdgeGraph::new(g);
    assert!(DenseBackend::for_graph(&rt, &manifest, eg.n()).is_err());
}

#[test]
fn local_artifact_one_round() {
    let Some((rt, manifest)) = runtime_or_skip() else { return };
    let b = manifest.support_blocks()[0];
    if !manifest.has(&format!("local_{b}")) {
        return;
    }
    // bowtie: triangles {0,1,2} and {2,3,4}; all supports 1 — the local
    // round keeps rho=1 everywhere (each triangle supports its edges)
    let mut a = vec![0f32; b * b];
    for (u, v) in [(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (2, 4)] {
        a[u * b + v] = 1.0;
        a[v * b + u] = 1.0;
    }
    let a_lit = literal_matrix(&a, b, b).unwrap();
    let s = rt
        .execute_f32(&format!("support_{b}"), &[literal_matrix(&a, b, b).unwrap()])
        .unwrap()
        .remove(0);
    let rho = literal_matrix(&s, b, b).unwrap();
    let out = rt
        .execute_f32(&format!("local_{b}"), &[a_lit, rho])
        .unwrap();
    assert_eq!(out[0], s, "bowtie supports are already the fixpoint");
}
