//! Cross-module integration tests: the full pipeline over generated and
//! file-loaded graphs, cross-algorithm agreement at scale, the paper's
//! worked example, and the coordinator server under concurrent load.

use trussx::coordinator::{run_job, serve, Algorithm, Client, GraphSpec, JobConfig};
use trussx::gen;
use trussx::graph::{io, EdgeGraph, GraphBuilder};
use trussx::kcore;
use trussx::order::{self, Ordering};
use trussx::par::Pool;
use trussx::truss;

/// The paper's Figure 1 properties on a faithful instance: all
/// coreness 3 is not reproducible with two disjoint triangles, so use
/// the figure's actual structure — two dense blocks (each a K4) joined
/// by a single edge: coreness 3 everywhere, bridge trussness 2, block
/// edges trussness 4, two maximal k-trusses for k = 3.
#[test]
fn fig1_example_core_and_truss() {
    let mut edges = vec![];
    for base in [0u32, 4] {
        for i in 0..4 {
            for j in (i + 1)..4 {
                edges.push((base + i, base + j));
            }
        }
    }
    edges.push((3, 4)); // bridge
    let g = GraphBuilder::new().edges_vec(edges).build();
    let core = kcore::bz(&g);
    assert!(core.iter().all(|&c| c == 3), "coreness: {core:?}");
    let eg = EdgeGraph::new(g);
    let res = truss::pkt(&eg, &Pool::new(2));
    let bridge = eg.edge_id(3, 4).unwrap() as usize;
    assert_eq!(res.trussness[bridge], 2);
    for (e, &t) in res.trussness.iter().enumerate() {
        if e != bridge {
            assert_eq!(t, 4, "edge {e}");
        }
    }
    let trusses = truss::ktruss_components(&eg, &res.trussness, 3);
    assert_eq!(trusses.len(), 2, "two maximal 3-trusses (the two K4s)");
}

/// All four algorithms agree edge-for-edge on every suite graph family
/// (subsampled sizes to keep test time bounded).
#[test]
fn all_algorithms_agree_across_families() {
    let graphs = vec![
        ("rmat", gen::rmat(512, 3000, 0.57, 0.19, 0.19, 5)),
        ("er", gen::erdos_renyi(600, 0.015, 6)),
        ("ba", gen::barabasi_albert(500, 4, 7)),
        ("ws", gen::watts_strogatz(400, 4, 0.1, 8)),
        ("pp", gen::planted_partition(6, 18, 0.7, 0.01, 9)),
    ];
    for (name, g) in graphs {
        let (g, _) = order::reorder(&g, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let p1 = truss::pkt(&eg, &Pool::new(1)).trussness;
        let p4 = truss::pkt(&eg, &Pool::new(4)).trussness;
        let w = truss::wc(&eg).trussness;
        let r = truss::ros(&eg, &Pool::new(2)).trussness;
        let l = truss::local(&eg, &Pool::new(2), 1_000_000).trussness;
        assert_eq!(p1, p4, "{name}: pkt thread invariance");
        assert_eq!(p1, w, "{name}: pkt vs wc");
        assert_eq!(p1, r, "{name}: pkt vs ros");
        assert_eq!(p1, l, "{name}: pkt vs local");
    }
}

/// Ordering changes edge ids but never the trussness multiset, and the
/// per-edge values map through the permutation.
#[test]
fn ordering_permutes_trussness_consistently() {
    let g = gen::rmat(256, 1500, 0.6, 0.18, 0.18, 11);
    let eg_nat = EdgeGraph::new(g.clone());
    let res_nat = truss::pkt(&eg_nat, &Pool::new(2));
    let (gk, perm) = order::reorder(&g, Ordering::KCore);
    let eg_kco = EdgeGraph::new(gk);
    let res_kco = truss::pkt(&eg_kco, &Pool::new(2));
    for (e, &(u, v)) in eg_nat.el.iter().enumerate() {
        let (pu, pv) = (perm[u as usize], perm[v as usize]);
        let e2 = eg_kco.edge_id(pu, pv).expect("edge survives relabel") as usize;
        assert_eq!(res_nat.trussness[e], res_kco.trussness[e2]);
    }
    let _ = res_kco;
}

/// Round-trip through file I/O preserves decomposition results.
#[test]
fn file_roundtrip_preserves_decomposition() {
    let g = gen::planted_partition(3, 12, 0.8, 0.02, 12);
    let dir = std::env::temp_dir().join("trussx_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("g.el");
    io::write_edge_list(&g, &p).unwrap();
    let g2 = io::read_edge_list(&p).unwrap();
    assert_eq!(g, g2);
    let t1 = truss::pkt(&EdgeGraph::new(g), &Pool::new(2)).trussness;
    let t2 = truss::pkt(&EdgeGraph::new(g2), &Pool::new(2)).trussness;
    assert_eq!(t1, t2);
}

/// The k-truss/k-core containment theorem (Cohen): every edge of a
/// k-truss has both endpoints in the (k−1)-core.
#[test]
fn ktruss_subset_of_kcore() {
    let g = gen::rmat(512, 4000, 0.57, 0.19, 0.19, 13);
    let core = kcore::bz(&g);
    let eg = EdgeGraph::new(g);
    let res = truss::pkt(&eg, &Pool::new(2));
    for (e, &(u, v)) in eg.el.iter().enumerate() {
        let t = res.trussness[e];
        assert!(
            core[u as usize] + 1 >= t && core[v as usize] + 1 >= t,
            "edge <{u},{v}> trussness {t} vs coreness ({}, {})",
            core[u as usize],
            core[v as usize]
        );
    }
}

/// Pipeline + server end to end with concurrent clients.
#[test]
fn server_pipeline_concurrent() {
    let h = serve("127.0.0.1:0").unwrap();
    let addr = h.addr;
    let threads: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .request(&format!(
                        "DECOMP pp:blocks=3,size=10,pin=0.8,pout=0.02,seed={i} algo=pkt threads=2"
                    ))
                    .unwrap();
                assert!(r.starts_with("OK "), "{r}");
                let r = c.request(&format!("HIST complete:n={}", 4 + i)).unwrap();
                assert!(r.starts_with("OK "), "{r}");
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(h.jobs_served(), 6);
    h.shutdown();
}

/// JobConfig coverage: every algorithm through the public pipeline on
/// a graph with non-trivial truss structure.
#[test]
fn pipeline_reports_consistent_metadata() {
    let spec = GraphSpec::parse("ba:n=300,k=5,seed=21").unwrap();
    for algo in [Algorithm::Pkt, Algorithm::Wc, Algorithm::Ros, Algorithm::Local] {
        let r = run_job(&JobConfig::new(spec.clone()).algorithm(algo).threads(2)).unwrap();
        assert_eq!(r.m, r.trussness.len());
        assert_eq!(r.histogram.iter().sum::<u64>(), r.m as u64);
        assert_eq!(r.t_max as usize, r.histogram.len() - 1);
        assert!(r.gweps > 0.0);
    }
}

/// Wedge-count workloads: decomposition time is recorded per phase and
/// phases sum below total (sanity for Fig. 4 benches).
#[test]
fn phase_times_consistent() {
    let g = gen::rmat(1024, 8000, 0.57, 0.19, 0.19, 22);
    let (g, _) = order::reorder(&g, Ordering::KCore);
    let eg = EdgeGraph::new(g);
    let res = truss::pkt(&eg, &Pool::new(2));
    let s = &res.stats;
    assert!(s.support_secs > 0.0);
    assert!(s.scan_secs > 0.0);
    assert!(s.process_secs > 0.0);
    assert!(
        s.support_secs + s.scan_secs + s.process_secs <= s.total_secs * 1.05,
        "phases {:?} exceed total {}",
        (s.support_secs, s.scan_secs, s.process_secs),
        s.total_secs
    );
}
