//! The `std`/`loom` synchronization shim.
//!
//! Every atomic, `Arc`, `Mutex`, thread handle, and unsafe cell used by
//! concurrency-bearing code goes through this module instead of
//! `std::sync` directly (the `pallas lint` pass enforces this for
//! `std::sync::atomic`). A normal build re-exports `std`; building with
//! `RUSTFLAGS="--cfg loom"` swaps in the `loom` model checker's
//! instrumented replacements, so the model tests in `par::loom_model`
//! can exhaustively explore thread schedules and catch real memory-order
//! bugs instead of whatever interleavings one machine happens to produce.
//!
//! `loom` is not declared in `Cargo.toml` — the offline registry does
//! not carry it (same policy as the `xla` feature's missing dependency).
//! The CI loom job adds it on the fly; locally:
//!
//! ```text
//! cargo add loom
//! RUSTFLAGS="--cfg loom" cargo test -p trussx --lib loom_
//! ```

/// The atomic types and `Ordering` (`std::sync::atomic` or loom's).
#[cfg(not(loom))]
pub use std::sync::atomic;

#[cfg(loom)]
pub use loom::sync::atomic;

#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex};

#[cfg(loom)]
pub use loom::sync::{Arc, Mutex};

#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::thread;

/// Run a closure under loom's exhaustive scheduler (model tests only).
#[cfg(loom)]
pub use loom::model;

#[cfg(loom)]
pub use loom::cell::UnsafeCell;

/// A `loom::cell::UnsafeCell`-shaped wrapper over [`std::cell::UnsafeCell`].
///
/// Loom's cell only grants access through `with`/`with_mut` closures so
/// it can track every read/write and fail the model on an unsynchronized
/// pair; production code adopts the same closure API so one source text
/// compiles against both. The wrapper itself stays safe — it only hands
/// out raw pointers, and each dereference site carries its own `SAFETY:`
/// justification.
#[cfg(not(loom))]
#[derive(Debug, Default)]
#[repr(transparent)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

#[cfg(not(loom))]
impl<T> UnsafeCell<T> {
    #[inline]
    pub fn new(data: T) -> Self {
        Self(std::cell::UnsafeCell::new(data))
    }

    /// Shared access: the closure receives a `*const T` it may read if
    /// no concurrent writer exists (loom verifies this; std trusts the
    /// caller's protocol).
    #[inline]
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        f(self.0.get())
    }

    /// Exclusive access: the closure receives a `*mut T` it may write if
    /// no other access is concurrent (loom verifies this; std trusts the
    /// caller's protocol).
    #[inline]
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}
