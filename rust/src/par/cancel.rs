//! Cooperative cancellation for long-running jobs.
//!
//! A [`CancelToken`] is a cheap clonable handle combining an explicit
//! cancel flag with an optional wall-clock deadline. Long-running
//! algorithms poll [`CancelToken::should_stop`] at their natural
//! synchronization points — the PKT/k-core level boundaries and the
//! triangle-count chunk boundaries — and unwind with a [`Cancelled`]
//! error carrying partial-progress detail instead of running to
//! completion. Nothing is preempted: a token only takes effect where the
//! algorithm chooses to look at it, which keeps the level-synchronous
//! invariants intact (a stage always finishes the level it is in).
//!
//! Like the rest of `par`, the flag goes through the [`super::sync`]
//! shim; the module itself is `cfg(not(loom))` (it leans on `Instant`,
//! which loom cannot model — same policy as `par::runtime`).

use super::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Why a job was asked to stop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// The per-job deadline expired (`timeout=` / `--job-timeout`).
    Deadline,
    /// Explicitly cancelled (server drain, client gone).
    Cancelled,
}

impl CancelReason {
    /// Stable wire name (used in `ERR DEADLINE` / `ERR CANCELLED`).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Deadline => "DEADLINE",
            Self::Cancelled => "CANCELLED",
        }
    }
}

/// The error a cancelled job unwinds with. Carries where the job was
/// stopped and a free-form partial-progress summary so callers can
/// report how far the work got (the tentpole's "partial-stats
/// reporting").
#[derive(Clone, Debug)]
pub struct Cancelled {
    pub reason: CancelReason,
    /// The checkpoint that observed the stop, e.g. `pkt.level`.
    pub at: &'static str,
    /// Partial-progress detail, e.g. `levels=5 peeled=1234/5000`.
    pub partial: String,
}

impl Cancelled {
    /// One-line description for protocol replies and logs.
    pub fn describe(&self) -> String {
        if self.partial.is_empty() {
            format!("job stopped at {}", self.at)
        } else {
            format!("job stopped at {} ({})", self.at, self.partial)
        }
    }
}

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.reason.name(), self.describe())
    }
}

impl std::error::Error for Cancelled {}

/// A shared stop signal: explicit cancellation plus an optional
/// deadline, polled cooperatively. Clones share the cancel flag.
#[derive(Clone, Debug)]
pub struct CancelToken {
    cancelled: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that never fires — the default for direct API callers.
    pub fn never() -> Self {
        Self { cancelled: Arc::new(AtomicBool::new(false)), deadline: None }
    }

    /// A token that fires `timeout` from now (`None` = no deadline).
    pub fn with_timeout(timeout: Option<Duration>) -> Self {
        Self {
            cancelled: Arc::new(AtomicBool::new(false)),
            deadline: timeout.map(|t| Instant::now() + t),
        }
    }

    /// Request cancellation; every clone observes it.
    pub fn cancel(&self) {
        // ORDERING: Release pairs with the Acquire in `should_stop` —
        // same single-flag publish pattern as the server stop flag
        // (loom-checked shape: par::loom_model level-boundary publish).
        self.cancelled.store(true, Ordering::Release);
    }

    /// Poll the token: `Some(reason)` once the job should stop.
    /// Explicit cancellation wins over an expired deadline.
    pub fn should_stop(&self) -> Option<CancelReason> {
        // ORDERING: Acquire pairs with the Release in `cancel`.
        if self.cancelled.load(Ordering::Acquire) {
            return Some(CancelReason::Cancelled);
        }
        match self.deadline {
            Some(d) if Instant::now() >= d => Some(CancelReason::Deadline),
            _ => None,
        }
    }

    /// The deadline, if any (executors use it to pre-reject queued jobs
    /// whose budget is already spent).
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Build the [`Cancelled`] error for the current stop state; falls
    /// back to `Deadline` if the token raced back to not-stopped (the
    /// caller already committed to unwinding).
    pub fn stopped(&self, at: &'static str, partial: String) -> Cancelled {
        Cancelled {
            reason: self.should_stop().unwrap_or(CancelReason::Deadline),
            at,
            partial,
        }
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::never()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_token_never_fires() {
        let t = CancelToken::never();
        assert_eq!(t.should_stop(), None);
        assert!(t.deadline().is_none());
    }

    #[test]
    fn cancel_is_shared_across_clones() {
        let t = CancelToken::never();
        let c = t.clone();
        assert_eq!(c.should_stop(), None);
        t.cancel();
        assert_eq!(c.should_stop(), Some(CancelReason::Cancelled));
        assert_eq!(t.should_stop(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn deadline_fires_after_timeout() {
        let t = CancelToken::with_timeout(Some(Duration::from_millis(5)));
        // may or may not have fired yet; after sleeping it must have
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(t.should_stop(), Some(CancelReason::Deadline));
    }

    #[test]
    fn zero_timeout_fires_immediately() {
        let t = CancelToken::with_timeout(Some(Duration::ZERO));
        assert_eq!(t.should_stop(), Some(CancelReason::Deadline));
    }

    #[test]
    fn explicit_cancel_wins_over_deadline() {
        let t = CancelToken::with_timeout(Some(Duration::ZERO));
        t.cancel();
        assert_eq!(t.should_stop(), Some(CancelReason::Cancelled));
    }

    #[test]
    fn cancelled_error_renders() {
        let e = Cancelled {
            reason: CancelReason::Deadline,
            at: "pkt.level",
            partial: "levels=3 peeled=10/40".into(),
        };
        assert_eq!(e.to_string(), "DEADLINE: job stopped at pkt.level (levels=3 peeled=10/40)");
        let e2 = Cancelled { reason: CancelReason::Cancelled, at: "x", partial: String::new() };
        assert_eq!(e2.describe(), "job stopped at x");
        // downcasts through anyhow (the pipeline error path)
        let any: anyhow::Error = e.into();
        assert!(any.downcast_ref::<Cancelled>().is_some());
    }

    #[test]
    fn stopped_builds_error_with_reason() {
        let t = CancelToken::never();
        t.cancel();
        let e = t.stopped("kcore.level", "remaining=7".into());
        assert_eq!(e.reason, CancelReason::Cancelled);
        assert_eq!(e.at, "kcore.level");
    }
}
