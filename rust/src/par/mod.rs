//! The shared-memory parallel runtime substrate.
//!
//! The paper's OpenMP idioms, rebuilt on `std::thread` + atomics (no
//! external crates are available offline):
//!
//! - [`Pool::region`] — an OpenMP `parallel` region: `t` scoped threads
//!   run the same closure, coordinating through [`RegionCtx::barrier`];
//! - [`RegionCtx::for_dynamic`] — `omp for schedule(dynamic, chunk)`:
//!   work distributed chunk-at-a-time from a shared atomic counter;
//! - [`RegionCtx::for_static`] — `omp for schedule(static)`: contiguous
//!   per-thread slabs (used by the SCAN phase, like the paper);
//! - [`AtomicVec`] — a fixed-capacity concurrent append buffer: the
//!   `curr`/`next` frontier arrays with the paper's thread-local `buff`
//!   batching (one atomic fetch-add per `s` items instead of per item).
//!
//! All synchronization primitives come from the [`sync`] shim, so the
//! lock-free pieces (`AtomicVec`, [`AtomicBitset`]) compile against the
//! `loom` model checker under `RUSTFLAGS="--cfg loom"` and their
//! happens-before protocols are exhaustively checked by the
//! `loom_model` tests. The thread-pool half ([`Pool`]/[`RegionCtx`])
//! stays `std`-only: loom has no scoped threads or barriers, and the
//! region barrier is itself the synchronization the models reproduce
//! with an explicit release/acquire publish.

pub mod sync;

#[cfg(not(loom))]
pub mod cancel;
#[cfg(not(loom))]
mod runtime;
#[cfg(not(loom))]
pub use cancel::{CancelReason, CancelToken, Cancelled};
#[cfg(not(loom))]
pub use runtime::{Counter, Pool, RegionCtx};

#[cfg(all(test, loom))]
mod loom_model;

use self::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use self::sync::UnsafeCell;
use std::mem::MaybeUninit;

/// Default chunk sizes from the paper's §4.1 (support computation: 10,
/// edge processing: 4).
pub const CHUNK_SUPPORT: usize = 10;
pub const CHUNK_PROCESS: usize = 4;
/// Thread-local frontier buffer size (`buff` in Alg. 4/5).
pub const BUFF_SIZE: usize = 256;

/// Fixed-capacity vector supporting concurrent batched appends — the
/// `curr` / `next` frontier arrays of Alg. 4/5.
///
/// Safety model: writers reserve disjoint ranges with one `fetch_add`
/// and copy their batch into the reservation; reads of `as_slice` must
/// be separated from writes by a barrier (the level-synchronous
/// structure guarantees this). `clear` must also be barrier-separated.
///
/// Storage is one [`sync::UnsafeCell`] *per slot*, not a single cell
/// around the whole buffer: concurrent writers then take raw pointers to
/// disjoint cells and never materialize overlapping `&mut` references to
/// the shared buffer, which the previous single-cell layout did — that
/// is undefined behavior under Stacked Borrows even when the written
/// ranges are disjoint, and both Miri and loom reject it.
pub struct AtomicVec<T: Copy> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    len: AtomicUsize,
}

// SAFETY: `AtomicVec` hands shared references across threads, so it must
// justify `Sync`/`Send` itself: (1) writers reserve disjoint slot ranges
// with one atomic `fetch_add` on `len`, so no two threads ever write the
// same slot between two `clear` calls; (2) reads (`as_slice`/`snapshot`)
// are only legal once a happens-before edge (region barrier, join, or a
// release/acquire publish) separates them from all writes — the
// level-synchronous peel provides exactly that, and the loom models in
// `par::loom_model` check the protocol; (3) `T: Copy` keeps drops
// trivial, so an uninitialized tail beyond `len` is never touched.
unsafe impl<T: Copy + Send> Send for AtomicVec<T> {}
// SAFETY: see the `Send` impl directly above — disjoint reservations
// plus barrier-separated reads make shared `&self` use race-free.
unsafe impl<T: Copy + Send> Sync for AtomicVec<T> {}

impl<T: Copy> AtomicVec<T> {
    /// An empty vector with room for `cap` elements. All slots start
    /// uninitialized; no `unsafe` is needed because `MaybeUninit` slots
    /// are valid in any state.
    pub fn with_capacity(cap: usize) -> Self {
        let slots = (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Self { slots, len: AtomicUsize::new(0) }
    }

    /// Append a batch; returns the start offset of the reservation.
    /// Panics if capacity would be exceeded (frontiers are pre-sized to
    /// `m`, which is a hard upper bound).
    pub fn push_batch(&self, items: &[T]) -> usize {
        // ORDERING: the fetch_add only needs atomicity — it hands out
        // disjoint reservations. It does NOT publish the slot contents
        // (they are written after it); publication to readers is the
        // caller's barrier/join. AcqRel keeps the counter itself ordered
        // against `clear`'s release store on reuse across phases.
        let start = self.len.fetch_add(items.len(), Ordering::AcqRel);
        assert!(
            start + items.len() <= self.slots.len(),
            "AtomicVec overflow: {} + {} > {}",
            start,
            items.len(),
            self.slots.len()
        );
        for (i, &x) in items.iter().enumerate() {
            // SAFETY: slots [start, start+items.len()) were reserved
            // exclusively for this thread by the fetch_add above; no
            // other thread writes them, and no reader touches them until
            // a later barrier orders these writes before its reads.
            self.slots[start + i].with_mut(|p| unsafe { p.write(MaybeUninit::new(x)) });
        }
        start
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the current contents. Caller must ensure no writer is
    /// concurrent (barrier-separated phases).
    #[cfg(not(loom))]
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        let len = self.len();
        let ptr = self.slots.as_ptr();
        // SAFETY: layout — `sync::UnsafeCell<MaybeUninit<T>>` is
        // repr(transparent) over `std::cell::UnsafeCell<MaybeUninit<T>>`,
        // which is repr(transparent) over `MaybeUninit<T>`, which has the
        // layout of `T`; the pointer cast is therefore sound. Init —
        // every slot below `len` was fully written before the barrier
        // separating writers from this reader. Aliasing — no `&mut` to
        // these slots exists while the shared slice lives, because
        // writes only happen in barrier-separated phases.
        unsafe { std::slice::from_raw_parts(ptr as *const T, len) }
    }

    /// Owned copy of the published prefix. Same protocol as
    /// [`AtomicVec::as_slice`]; this is the read path the loom models
    /// use, since loom requires every cell access to go through
    /// `with`/`with_mut`.
    pub fn snapshot(&self) -> Vec<T> {
        #[cfg(not(loom))]
        {
            self.as_slice().to_vec()
        }
        #[cfg(loom)]
        {
            let len = self.len();
            (0..len)
                // SAFETY: slots below `len` were initialized by writers
                // that happen-before this read (barrier/join/publish);
                // loom verifies that edge on every `with` access.
                .map(|i| self.slots[i].with(|p| unsafe { (*p).assume_init() }))
                .collect()
        }
    }

    /// Reset length to zero (single-threaded, barrier-separated).
    #[inline]
    pub fn clear(&self) {
        self.len.store(0, Ordering::Release);
    }
}

/// Per-thread buffered writer into an [`AtomicVec`] — the paper's `buff`
/// trick reducing atomic ops from O(|next|) to O(|next| / s).
pub struct BatchWriter<'a, T: Copy> {
    target: &'a AtomicVec<T>,
    buf: Vec<T>,
}

impl<'a, T: Copy> BatchWriter<'a, T> {
    pub fn new(target: &'a AtomicVec<T>) -> Self {
        Self { target, buf: Vec::with_capacity(BUFF_SIZE) }
    }

    #[inline]
    pub fn push(&mut self, x: T) {
        self.buf.push(x);
        if self.buf.len() == BUFF_SIZE {
            self.flush();
        }
    }

    #[inline]
    pub fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.target.push_batch(&self.buf);
            self.buf.clear();
        }
    }
}

impl<T: Copy> Drop for BatchWriter<'_, T> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Fixed-length concurrent bitset: one bit per flag, packed 64 per word,
/// mutated with word-level `fetch_or` / `fetch_and`.
///
/// This is the packed replacement for the peel's `Vec<AtomicBool>` flag
/// arrays (`processed` / `inCurr` / `inNext`): an 8× reduction in flag
/// memory and scan bandwidth, which is exactly the traffic the paper's
/// §4 identifies as the bottleneck on its 24-core server.
///
/// All operations are `Relaxed`: like the byte-wide flags they replace,
/// cross-phase visibility comes from the region barriers, not from the
/// flag accesses themselves. Two threads touching different bits of the
/// same word stay correct (the RMW is atomic — the loom model
/// `loom_bitset_rmw_no_lost_updates` checks it), they just contend.
pub struct AtomicBitset {
    words: Box<[AtomicU64]>,
    len: usize,
}

impl AtomicBitset {
    /// A bitset of `len` bits, all zero.
    pub fn new(len: usize) -> Self {
        let words = (0..len.div_ceil(64)).map(|_| AtomicU64::new(0)).collect();
        Self { words, len }
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6].load(Ordering::Relaxed) >> (i & 63)) & 1 != 0
    }

    /// Set bit `i` to 1.
    #[inline]
    pub fn set(&self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6].fetch_or(1 << (i & 63), Ordering::Relaxed);
    }

    /// Set bit `i` to 0.
    #[inline]
    pub fn clear(&self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6].fetch_and(!(1 << (i & 63)), Ordering::Relaxed);
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.load(Ordering::Relaxed).count_ones() as usize).sum()
    }

    /// Zero every bit (single-threaded, barrier-separated).
    pub fn clear_all(&self) {
        for w in self.words.iter() {
            w.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn atomic_vec_concurrent_batches() {
        // Miri executes this race-heavy test under its interpreter:
        // shrink the volume so it finishes, keep the full size natively
        let per: u32 = if cfg!(miri) { 600 } else { 10_000 };
        let av: AtomicVec<u32> = AtomicVec::with_capacity(4 * per as usize);
        let pool = Pool::new(4);
        pool.region(|ctx| {
            let mut w = BatchWriter::new(&av);
            for i in 0..per {
                w.push(ctx.tid as u32 * per + i);
            }
        });
        assert_eq!(av.len(), 4 * per as usize);
        let mut all: Vec<u32> = av.as_slice().to_vec();
        all.sort_unstable();
        assert_eq!(all, (0..4 * per).collect::<Vec<_>>());
    }

    #[test]
    fn atomic_vec_clear_reuse() {
        let av: AtomicVec<u32> = AtomicVec::with_capacity(8);
        av.push_batch(&[1, 2, 3]);
        assert_eq!(av.as_slice(), &[1, 2, 3]);
        assert_eq!(av.snapshot(), vec![1, 2, 3]);
        av.clear();
        assert!(av.is_empty());
        av.push_batch(&[9]);
        assert_eq!(av.as_slice(), &[9]);
    }

    #[test]
    #[should_panic(expected = "AtomicVec overflow")]
    fn atomic_vec_overflow_panics() {
        let av: AtomicVec<u32> = AtomicVec::with_capacity(2);
        av.push_batch(&[1, 2, 3]);
    }

    #[test]
    fn bitset_basic_ops() {
        // length deliberately not a multiple of 64: the last word is
        // partial and word-boundary bits (63, 64, 65) must not alias
        let bs = AtomicBitset::new(130);
        assert_eq!(bs.len(), 130);
        assert!(!bs.is_empty());
        assert_eq!(bs.count_ones(), 0);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!bs.get(i));
            bs.set(i);
            assert!(bs.get(i), "bit {i}");
        }
        assert_eq!(bs.count_ones(), 8);
        // neighbors of the set bits stayed clear
        for i in [2usize, 62, 66, 126] {
            assert!(!bs.get(i), "bit {i}");
        }
        bs.clear(64);
        assert!(!bs.get(64));
        assert!(bs.get(63) && bs.get(65), "clear must not touch siblings");
        assert_eq!(bs.count_ones(), 7);
        bs.clear_all();
        assert_eq!(bs.count_ones(), 0);
    }

    #[test]
    fn bitset_empty() {
        let bs = AtomicBitset::new(0);
        assert!(bs.is_empty());
        assert_eq!(bs.count_ones(), 0);
    }

    #[test]
    fn bitset_concurrent_interleaved_sets() {
        // 4 threads set interleaved bits (thread t owns bits ≡ t mod 4),
        // so every word is hammered by all threads concurrently; no set
        // may be lost and no foreign bit may appear
        let total = if cfg!(miri) { 64 * 3 + 13 } else { 64 * 37 + 13 };
        let bs = AtomicBitset::new(total);
        let pool = Pool::new(4);
        pool.region(|ctx| {
            let mut i = ctx.tid;
            while i < total {
                bs.set(i);
                i += ctx.nthreads;
            }
        });
        assert_eq!(bs.count_ones(), total);
        // clear every other bit concurrently; the rest must survive
        pool.region(|ctx| {
            let mut i = ctx.tid * 2;
            while i < total {
                bs.clear(i);
                i += ctx.nthreads * 2;
            }
        });
        assert_eq!(bs.count_ones(), total / 2);
        for i in 0..total {
            assert_eq!(bs.get(i), i % 2 == 1, "bit {i}");
        }
    }
}
