//! The thread-pool half of the runtime: OpenMP-style regions, barriers,
//! and the static/dynamic schedulers.
//!
//! Kept out of the loom build (`cfg(not(loom))` in `par`): loom models
//! neither scoped threads nor `std::sync::Barrier`, and the lock-free
//! structures it *does* model ([`super::AtomicVec`],
//! [`super::AtomicBitset`]) live in the parent module. Atomics still go
//! through the [`super::sync`] shim so the whole crate has a single
//! audited import point.

use super::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, OnceLock};
use std::time::Instant;

/// Load-imbalance ratio buckets (max-items / mean-items per region):
/// 1.0 is perfect balance, the tail captures pathological skew.
const IMBALANCE_BUCKETS: &[f64] = &[1.0, 1.02, 1.05, 1.1, 1.2, 1.5, 2.0, 3.0, 5.0, 10.0];

/// Cached handles into the global metric registry — looked up once,
/// then updated lock-free from inside regions.
struct ParObs {
    regions: crate::obs::Counter,
    chunks: crate::obs::Counter,
    items: crate::obs::Counter,
    barrier_waits: crate::obs::Counter,
    barrier_secs: crate::obs::Histogram,
    imbalance: crate::obs::Gauge,
    imbalance_hist: crate::obs::Histogram,
}

fn par_obs() -> &'static ParObs {
    static OBS: OnceLock<ParObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = crate::obs::global();
        ParObs {
            regions: r.counter("par_regions_total", &[]),
            chunks: r.counter("par_chunks_dispatched_total", &[]),
            items: r.counter("par_items_total", &[]),
            barrier_waits: r.counter("par_barrier_waits_total", &[]),
            barrier_secs: r.histogram("par_barrier_wait_seconds", &[]),
            imbalance: r.gauge("par_load_imbalance", &[]),
            imbalance_hist: r.histogram_with_buckets(
                "par_load_imbalance_ratio",
                &[],
                IMBALANCE_BUCKETS,
            ),
        }
    })
}

/// A parallel execution pool. Threads are spawned per region (scoped),
/// so a `Pool` is just a thread-count policy object; persistent state
/// (counters, frontiers) lives in the algorithm.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    nthreads: usize,
}

impl Pool {
    pub fn new(nthreads: usize) -> Self {
        Self { nthreads: nthreads.max(1) }
    }

    /// Thread count from `TRUSSX_THREADS` or the machine's parallelism.
    pub fn default_threads() -> usize {
        std::env::var("TRUSSX_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            })
    }

    pub fn with_default_threads() -> Self {
        Self::new(Self::default_threads())
    }

    #[inline]
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Run an OpenMP-style parallel region: `nthreads` threads execute
    /// `f(&ctx)`; the call returns when all threads finish. With one
    /// thread the closure runs inline (no spawn overhead) — this is the
    /// path sequential baselines use.
    pub fn region<F>(&self, f: F)
    where
        F: Fn(&RegionCtx) + Sync,
    {
        let t = self.nthreads;
        let obs = par_obs();
        obs.regions.inc();
        let barrier = Barrier::new(t);
        let item_counts: Vec<AtomicU64> = (0..t).map(|_| AtomicU64::new(0)).collect();
        if t == 1 {
            f(&RegionCtx { tid: 0, nthreads: 1, barrier: &barrier, items: &item_counts[0] });
        } else {
            std::thread::scope(|scope| {
                for tid in 0..t {
                    let f = &f;
                    let barrier = &barrier;
                    let items = &item_counts[tid];
                    scope.spawn(move || {
                        f(&RegionCtx { tid, nthreads: t, barrier, items });
                    });
                }
            });
        }
        // per-region load accounting: total items done, and how far the
        // busiest thread ran ahead of the mean (1.0 = perfectly balanced)
        let per_thread: Vec<u64> = item_counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = per_thread.iter().sum();
        if total > 0 {
            obs.items.add(total);
            if t > 1 {
                let max = *per_thread.iter().max().unwrap_or(&0);
                let ratio = max as f64 * t as f64 / total as f64;
                obs.imbalance.set(ratio);
                obs.imbalance_hist.observe(ratio);
            }
        }
    }

    /// One-shot dynamic parallel-for over `0..total` (its own region).
    pub fn for_dynamic<F>(&self, total: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let counter = AtomicUsize::new(0);
        self.region(|ctx| {
            dynamic_items(&counter, total, chunk, ctx.items, &f);
        });
    }
}

/// Per-thread context inside a [`Pool::region`].
pub struct RegionCtx<'a> {
    pub tid: usize,
    pub nthreads: usize,
    barrier: &'a Barrier,
    /// Items this thread has executed in this region (load accounting;
    /// fed by `for_dynamic` / `for_static`).
    items: &'a AtomicU64,
}

impl RegionCtx<'_> {
    /// OpenMP `barrier`. Counted and timed: waiting at a barrier is
    /// exactly the load-imbalance cost the paper's §4 discusses.
    #[inline]
    pub fn barrier(&self) {
        let obs = par_obs();
        obs.barrier_waits.inc();
        let t0 = Instant::now();
        self.barrier.wait();
        obs.barrier_secs.observe(t0.elapsed().as_secs_f64());
    }

    /// `schedule(dynamic, chunk)` over `0..total`, driven by a shared
    /// counter the caller resets between uses (see [`Counter`]).
    #[inline]
    pub fn for_dynamic<F>(&self, counter: &Counter, total: usize, chunk: usize, f: F)
    where
        F: FnMut(usize),
    {
        dynamic_items(&counter.0, total, chunk, self.items, f);
    }

    /// [`RegionCtx::for_dynamic`] with an early-exit predicate: `stop()`
    /// is re-checked before claiming each chunk, so a cooperative cancel
    /// (see [`super::cancel`]) takes effect within one chunk of work
    /// rather than one full parallel-for. Items already claimed are
    /// always completed — partial chunks never happen.
    #[inline]
    pub fn for_dynamic_until<F, S>(
        &self,
        counter: &Counter,
        total: usize,
        chunk: usize,
        stop: S,
        f: F,
    ) where
        F: FnMut(usize),
        S: Fn() -> bool,
    {
        dynamic_items_until(&counter.0, total, chunk, self.items, stop, f);
    }

    /// `schedule(static)` over `0..total`: thread `tid` gets the
    /// contiguous range `[lo, hi)`.
    #[inline]
    pub fn static_range(&self, total: usize) -> (usize, usize) {
        let per = total.div_ceil(self.nthreads);
        let lo = (self.tid * per).min(total);
        let hi = ((self.tid + 1) * per).min(total);
        (lo, hi)
    }

    /// Convenience static-schedule loop.
    #[inline]
    pub fn for_static<F>(&self, total: usize, mut f: F)
    where
        F: FnMut(usize),
    {
        let (lo, hi) = self.static_range(total);
        for i in lo..hi {
            f(i);
        }
        if hi > lo {
            self.items.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        }
    }
}

#[inline]
fn dynamic_items<F>(counter: &AtomicUsize, total: usize, chunk: usize, items: &AtomicU64, f: F)
where
    F: FnMut(usize),
{
    dynamic_items_until(counter, total, chunk, items, || false, f);
}

#[inline]
fn dynamic_items_until<F, S>(
    counter: &AtomicUsize,
    total: usize,
    chunk: usize,
    items: &AtomicU64,
    stop: S,
    mut f: F,
) where
    F: FnMut(usize),
    S: Fn() -> bool,
{
    let chunk = chunk.max(1);
    let obs = par_obs();
    let mut done = 0u64;
    let mut chunks = 0u64;
    loop {
        if stop() {
            break;
        }
        let start = counter.fetch_add(chunk, Ordering::Relaxed);
        if start >= total {
            break;
        }
        let end = (start + chunk).min(total);
        chunks += 1;
        done += (end - start) as u64;
        for i in start..end {
            f(i);
        }
    }
    if chunks > 0 {
        obs.chunks.add(chunks);
        items.fetch_add(done, Ordering::Relaxed);
    }
}

/// A resettable shared work counter for dynamic scheduling inside a
/// region. Reset from a single thread between barriers.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }

    /// Reset to zero (call from one thread, between barriers).
    #[inline]
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::super::sync::atomic::AtomicBool;
    use super::*;

    #[test]
    fn region_runs_all_threads() {
        let pool = Pool::new(4);
        let hits = AtomicU64::new(0);
        pool.region(|ctx| {
            hits.fetch_add(1 << (8 * ctx.tid), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0x01010101);
    }

    #[test]
    fn single_thread_region_inline() {
        let pool = Pool::new(1);
        // would not compile with FnMut across threads; single-thread path
        // still must run exactly once
        let hit_cell = AtomicBool::new(false);
        pool.region(|ctx| {
            assert_eq!(ctx.nthreads, 1);
            hit_cell.store(true, Ordering::Relaxed);
        });
        assert!(hit_cell.load(Ordering::Relaxed));
    }

    #[test]
    fn dynamic_for_covers_all_items_once() {
        let pool = Pool::new(4);
        let total = if cfg!(miri) { 507 } else { 10_007 };
        let marks: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
        pool.for_dynamic(total, 7, |i| {
            marks[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(marks.iter().all(|m| m.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_until_stops_between_chunks() {
        let pool = Pool::new(4);
        let total = 10_000;
        let hit = AtomicU64::new(0);
        let stop_flag = AtomicBool::new(false);
        let counter = Counter::new();
        pool.region(|ctx| {
            ctx.for_dynamic_until(
                &counter,
                total,
                7,
                || stop_flag.load(Ordering::Relaxed),
                |i| {
                    if i == 42 {
                        stop_flag.store(true, Ordering::Relaxed);
                    }
                    hit.fetch_add(1, Ordering::Relaxed);
                },
            );
        });
        let done = hit.load(Ordering::Relaxed);
        assert!(done >= 1, "some work ran");
        assert!(done < total as u64, "stop flag must cut the loop short: {done}");
    }

    #[test]
    fn dynamic_until_without_stop_covers_everything() {
        let pool = Pool::new(3);
        let total = 1009;
        let marks: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
        let counter = Counter::new();
        pool.region(|ctx| {
            ctx.for_dynamic_until(&counter, total, 5, || false, |i| {
                marks[i].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(marks.iter().all(|m| m.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn static_ranges_partition() {
        let pool = Pool::new(3);
        let ctxs: Vec<(usize, usize)> = {
            let out: Vec<_> = (0..3)
                .map(|tid| {
                    let ctx = RegionCtx {
                        tid,
                        nthreads: 3,
                        barrier: &Barrier::new(1),
                        items: &AtomicU64::new(0),
                    };
                    ctx.static_range(10)
                })
                .collect();
            out
        };
        let _ = pool;
        assert_eq!(ctxs, vec![(0, 4), (4, 8), (8, 10)]);
    }

    #[test]
    fn regions_record_work_metrics() {
        // the registry is process-global and shared with other tests, so
        // assert monotone deltas rather than absolute values
        let obs = par_obs();
        let (r0, i0, c0, b0) = (
            obs.regions.get(),
            obs.items.get(),
            obs.chunks.get(),
            obs.barrier_waits.get(),
        );
        let pool = Pool::new(3);
        let total = 1000;
        pool.for_dynamic(total, 7, |_| {});
        pool.region(|ctx| {
            ctx.for_static(total, |_| {});
            ctx.barrier();
        });
        // other tests may run concurrently, so the deltas are lower bounds
        assert!(obs.regions.get() - r0 >= 2);
        assert!(obs.items.get() - i0 >= 2 * total as u64);
        assert!(obs.chunks.get() - c0 >= total.div_ceil(7) as u64);
        assert!(obs.barrier_waits.get() - b0 >= 3, "one wait per thread");
    }

    #[test]
    fn barrier_separates_phases() {
        let pool = Pool::new(4);
        let phase1 = AtomicUsize::new(0);
        let ok = AtomicUsize::new(0);
        pool.region(|ctx| {
            // ORDERING: Relaxed is enough — the barrier between the two
            // phases is the synchronization under test; it must order
            // every phase-1 increment before every phase-2 load without
            // help from the accesses themselves. (SeqCst here would mask
            // a broken barrier, which is exactly what the test is for.)
            phase1.fetch_add(1, Ordering::Relaxed);
            ctx.barrier();
            // after the barrier every thread must observe all 4 phase-1
            // increments
            if phase1.load(Ordering::Relaxed) == 4 {
                ok.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn counter_reset() {
        let c = Counter::new();
        c.0.fetch_add(5, Ordering::Relaxed);
        c.reset();
        assert_eq!(c.0.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pool_threads_from_env_parse() {
        // just exercise the default path; value depends on machine
        assert!(Pool::default_threads() >= 1);
    }
}
