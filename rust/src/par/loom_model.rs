//! Exhaustive interleaving models for the `par` primitives.
//!
//! Built only under `RUSTFLAGS="--cfg loom"` (see [`super::sync`] for
//! how to run them). Each `model` call explores every schedule of its
//! threads under the C11 memory model; loom's instrumented cells
//! additionally fail any unsynchronized non-atomic access, so these
//! tests prove both the computed values *and* the happens-before edges
//! the `AtomicVec`/`AtomicBitset` safety comments claim.
//!
//! Thread counts stay at ≤ 3 (loom's practical limit): the protocols
//! are pairwise, so two racing threads plus the main thread already
//! cover every distinct interleaving class the peel produces.

use super::sync::atomic::{AtomicBool, AtomicI32, Ordering};
use super::sync::{model, thread, Arc};
use super::{AtomicBitset, AtomicVec};

/// Two writers race `push_batch`; after both join, the snapshot must
/// hold every element exactly once — the disjoint-reservation argument
/// of `AtomicVec`'s `Sync` impl. Loom also verifies no two `with_mut`
/// accesses to the same slot are ever unsynchronized.
#[test]
fn loom_atomicvec_disjoint_reservations() {
    model(|| {
        let av = Arc::new(AtomicVec::<u32>::with_capacity(4));
        let a = Arc::clone(&av);
        let b = Arc::clone(&av);
        let t1 = thread::spawn(move || {
            a.push_batch(&[1, 2]);
        });
        let t2 = thread::spawn(move || {
            b.push_batch(&[3]);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let mut got = av.snapshot();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
    });
}

/// The peel's flag transitions: `fetch_or` (enter inNext) racing
/// `fetch_and` (leave inCurr) on bits of the *same word*. Neither RMW
/// may lose the other's update.
#[test]
fn loom_bitset_rmw_no_lost_updates() {
    model(|| {
        let bs = Arc::new(AtomicBitset::new(8));
        bs.set(0); // pre-set: must survive the concurrent RMWs below
        let b1 = Arc::clone(&bs);
        let b2 = Arc::clone(&bs);
        let t1 = thread::spawn(move || b1.set(3));
        let t2 = thread::spawn(move || b2.clear(0));
        t1.join().unwrap();
        t2.join().unwrap();
        assert!(bs.get(3), "fetch_or lost against fetch_and");
        assert!(!bs.get(0), "fetch_and lost against fetch_or");
        assert_eq!(bs.count_ones(), 1);
    });
}

/// The level-boundary handoff: a writer fills the `next` frontier, then
/// publishes with a release store (standing in for the region barrier);
/// a reader that acquires the flag must see the *whole* frontier, not
/// just the length. This is the edge `as_slice`/`snapshot` rely on — the
/// `len` counter itself does not publish slot contents.
#[test]
fn loom_level_boundary_publish() {
    model(|| {
        let next = Arc::new(AtomicVec::<u32>::with_capacity(2));
        let ready = Arc::new(AtomicBool::new(false));
        let n = Arc::clone(&next);
        let r = Arc::clone(&ready);
        let t = thread::spawn(move || {
            n.push_batch(&[7, 8]);
            // ORDERING: Release pairs with the Acquire below; everything
            // written before this store is visible after that load.
            r.store(true, Ordering::Release);
        });
        if ready.load(Ordering::Acquire) {
            assert_eq!(next.snapshot(), vec![7, 8]);
        }
        t.join().unwrap();
    });
}

/// `truss::pkt::decrement`'s claim protocol at level `l` with `S[e] =
/// l + 1`: two racing decrementers, exactly one may observe the
/// `l+1 → l` transition (and so append the edge to the next frontier),
/// and the overshoot correction must leave `S[e] == l` in every
/// schedule.
#[test]
fn loom_decrement_claims_exactly_once() {
    model(|| {
        let s = Arc::new(AtomicI32::new(2));
        let level: i32 = 1;
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let s = Arc::clone(&s);
                thread::spawn(move || {
                    // mirror of pkt::decrement (Alg. 5 lines 17–28)
                    if s.load(Ordering::Relaxed) > level {
                        let old = s.fetch_sub(1, Ordering::AcqRel);
                        if old == level + 1 {
                            return 1; // claimed the transition
                        }
                        if old <= level {
                            s.fetch_add(1, Ordering::AcqRel); // overshoot undo
                        }
                    }
                    0
                })
            })
            .collect();
        let wins: i32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(wins, 1, "exactly one thread may claim the transition");
        assert_eq!(s.load(Ordering::Relaxed), level);
    });
}
