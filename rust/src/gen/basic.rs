//! Deterministic structured generators: complete graphs, rings, stars,
//! paths, and 2-D grids. Primarily used by tests (known truss values).

use crate::graph::{Graph, GraphBuilder, Vertex};

/// Complete graph K_n. Every edge of K_n has trussness n (each edge is in
/// n−2 triangles), making it the canonical truss test case.
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u as Vertex, v as Vertex));
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

/// Cycle C_n (n ≥ 3). Triangle-free for n > 3, so every edge has
/// trussness 2.
pub fn ring(n: usize) -> Graph {
    assert!(n >= 3);
    let mut edges = Vec::with_capacity(n);
    for u in 0..n {
        edges.push((u as Vertex, ((u + 1) % n) as Vertex));
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

/// Star S_n: vertex 0 connected to 1..n. Triangle-free; trussness 2.
pub fn star(n: usize) -> Graph {
    let edges: Vec<_> = (1..n).map(|v| (0 as Vertex, v as Vertex)).collect();
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

/// Simple path P_n.
pub fn path(n: usize) -> Graph {
    let edges: Vec<_> = (1..n).map(|v| ((v - 1) as Vertex, v as Vertex)).collect();
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

/// rows×cols 2-D grid (4-neighborhood). Triangle-free; trussness 2.
pub fn grid2d(rows: usize, cols: usize) -> Graph {
    let mut edges = Vec::new();
    let id = |r: usize, c: usize| (r * cols + c) as Vertex;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    GraphBuilder::new().num_vertices(rows * cols).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_counts() {
        let g = complete(6);
        assert_eq!(g.n(), 6);
        assert_eq!(g.m(), 15);
        assert_eq!(g.max_degree(), 5);
        // wedges of K_6: 6 * C(5,2) = 60
        assert_eq!(g.wedge_count(), 60);
    }

    #[test]
    fn ring_counts() {
        let g = ring(10);
        assert_eq!(g.n(), 10);
        assert_eq!(g.m(), 10);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn star_counts() {
        let g = star(8);
        assert_eq!(g.m(), 7);
        assert_eq!(g.degree(0), 7);
        assert_eq!(g.wedge_count(), 21);
    }

    #[test]
    fn grid_counts() {
        let g = grid2d(3, 4);
        assert_eq!(g.n(), 12);
        // 3*3 horizontal + 2*4 vertical = 9 + 8 = 17
        assert_eq!(g.m(), 17);
    }

    #[test]
    fn path_counts() {
        let g = path(5);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
    }
}
