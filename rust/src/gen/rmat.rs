//! R-MAT / Kronecker generator — skewed degree distributions like the
//! paper's social-network instances (soc-pokec, com-orkut, ...).

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::util::Rng;

/// Generate an R-MAT graph with `n` rounded up to the next power of two,
/// aiming for `m_target` distinct undirected edges. `(a, b, c)` are the
/// standard quadrant probabilities (d = 1 − a − b − c). Noise is added to
/// the quadrant probabilities per level (standard smoothing) to avoid
/// degenerate staircase degree plots.
pub fn rmat(n: usize, m_target: usize, a: f64, b: f64, c: f64, seed: u64) -> Graph {
    assert!(n >= 2);
    let d = 1.0 - a - b - c;
    assert!(a > 0.0 && b >= 0.0 && c >= 0.0 && d >= 0.0, "bad quadrant probs");
    let scale = (n as f64).log2().ceil() as u32;
    let n_pow = 1usize << scale;
    let mut rng = Rng::new(seed);
    // Oversample: dedup + self-loop removal eats some tuples.
    let attempts = m_target + m_target / 2 + 16;
    let mut edges = Vec::with_capacity(attempts);
    for _ in 0..attempts {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            // per-level jitter of ±10% keeps the distribution smooth
            let jitter = |x: f64, r: &mut Rng| x * (0.9 + 0.2 * r.f64());
            let (aj, bj, cj, dj) = (
                jitter(a, &mut rng),
                jitter(b, &mut rng),
                jitter(c, &mut rng),
                jitter(d, &mut rng),
            );
            let sum = aj + bj + cj + dj;
            let toss = rng.f64() * sum;
            u <<= 1;
            v <<= 1;
            if toss < aj {
                // top-left
            } else if toss < aj + bj {
                v |= 1;
            } else if toss < aj + bj + cj {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        if u != v {
            edges.push((u as Vertex, v as Vertex));
        }
    }
    GraphBuilder::new().num_vertices(n_pow).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_deterministic() {
        let a = rmat(1024, 4096, 0.57, 0.19, 0.19, 99);
        let b = rmat(1024, 4096, 0.57, 0.19, 0.19, 99);
        assert_eq!(a, b);
    }

    #[test]
    fn rmat_reaches_target_roughly() {
        let g = rmat(1024, 4096, 0.57, 0.19, 0.19, 1);
        // dedup removes some; expect within [0.5, 1.5] of target
        assert!(g.m() > 2048, "m={}", g.m());
        assert!(g.m() < 6144, "m={}", g.m());
    }

    #[test]
    fn rmat_skew_exceeds_er() {
        // RMAT with strong a-quadrant should have much higher max degree
        // than ER at equal density.
        let g_rmat = rmat(1024, 8192, 0.65, 0.15, 0.15, 3);
        let g_er = crate::gen::erdos_renyi(1024, 16.0 / 1023.0, 3);
        assert!(
            g_rmat.max_degree() > 2 * g_er.max_degree(),
            "rmat dmax {} vs er dmax {}",
            g_rmat.max_degree(),
            g_er.max_degree()
        );
    }

    #[test]
    fn rmat_valid() {
        rmat(256, 1024, 0.57, 0.19, 0.19, 5).validate();
    }
}
