//! Watts–Strogatz small-world generator — high clustering with low
//! diameter, approximating the locality of the paper's web-crawl
//! instances (in-2004, uk-2002: low wedge/triangle ratio).

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::util::Rng;

/// WS model: ring lattice where each vertex connects to its `k` nearest
/// neighbors on each side, then each lattice edge is rewired to a random
/// endpoint with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, seed: u64) -> Graph {
    assert!(k >= 1 && n > 2 * k, "need n > 2k");
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n * k);
    for u in 0..n {
        for off in 1..=k {
            let v = (u + off) % n;
            if rng.chance(beta) {
                // rewire the far endpoint
                let mut w = rng.range(0, n);
                let mut guard = 0;
                while (w == u || w == v) && guard < 16 {
                    w = rng.range(0, n);
                    guard += 1;
                }
                edges.push((u as Vertex, w as Vertex));
            } else {
                edges.push((u as Vertex, v as Vertex));
            }
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::triangle::count_triangles;

    #[test]
    fn ws_deterministic() {
        assert_eq!(
            watts_strogatz(100, 3, 0.1, 8),
            watts_strogatz(100, 3, 0.1, 8)
        );
    }

    #[test]
    fn ws_zero_beta_is_lattice() {
        let g = watts_strogatz(20, 2, 0.0, 1);
        assert_eq!(g.m(), 40);
        // each vertex sees u±1, u±2
        assert_eq!(g.degree(5), 4);
    }

    #[test]
    fn ws_lattice_has_triangles() {
        // k≥2 ring lattice is rich in triangles (u, u+1, u+2)
        let g = watts_strogatz(50, 2, 0.0, 1);
        assert_eq!(count_triangles(&g), 50);
    }

    #[test]
    fn ws_high_beta_reduces_clustering() {
        let lattice = watts_strogatz(300, 3, 0.0, 2);
        let random = watts_strogatz(300, 3, 1.0, 2);
        assert!(count_triangles(&lattice) > 3 * count_triangles(&random));
    }

    #[test]
    fn ws_valid() {
        watts_strogatz(64, 2, 0.3, 3).validate();
    }
}
