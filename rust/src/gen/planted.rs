//! Planted-partition (stochastic block model) generator — dense
//! communities with sparse inter-community edges. Used by the community
//! detection example and as the high-trussness web-crawl analogue
//! (hollywood-2009, indochina-2004: low wedge/triangle ratio, high t_max).

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::util::Rng;

/// `communities` blocks of `block_size` vertices; intra-block edge
/// probability `p_in`, inter-block probability `p_out`.
pub fn planted_partition(
    communities: usize,
    block_size: usize,
    p_in: f64,
    p_out: f64,
    seed: u64,
) -> Graph {
    assert!(communities >= 1 && block_size >= 1);
    let n = communities * block_size;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    let block_of = |u: usize| u / block_size;
    // intra-block: dense loop per block (block_size is small)
    for b in 0..communities {
        let base = b * block_size;
        for i in 0..block_size {
            for j in (i + 1)..block_size {
                if rng.chance(p_in) {
                    edges.push(((base + i) as Vertex, (base + j) as Vertex));
                }
            }
        }
    }
    // inter-block: geometric skipping over the full vertex-pair space,
    // keeping only cross-block pairs (p_out is small).
    if p_out > 0.0 && communities > 1 {
        let lq = (1.0 - p_out).ln();
        let (mut v, mut w): (i64, i64) = (1, -1);
        while (v as usize) < n {
            let r = 1.0 - rng.f64();
            w += 1 + (r.ln() / lq).floor() as i64;
            while w >= v && (v as usize) < n {
                w -= v;
                v += 1;
            }
            if (v as usize) < n && block_of(w as usize) != block_of(v as usize) {
                edges.push((w as Vertex, v as Vertex));
            }
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

/// Ground-truth community id of vertex `u` for a graph produced by
/// [`planted_partition`] with the same `block_size`.
pub fn planted_community(u: Vertex, block_size: usize) -> usize {
    u as usize / block_size
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_deterministic() {
        assert_eq!(
            planted_partition(4, 16, 0.8, 0.01, 3),
            planted_partition(4, 16, 0.8, 0.01, 3)
        );
    }

    #[test]
    fn planted_pure_blocks() {
        let g = planted_partition(3, 8, 1.0, 0.0, 1);
        // three disjoint K_8s
        assert_eq!(g.m(), 3 * 28);
        let (_, ncomp) = g.components();
        assert_eq!(ncomp, 3);
    }

    #[test]
    fn planted_intra_denser_than_inter() {
        let g = planted_partition(4, 25, 0.5, 0.01, 7);
        let mut intra = 0usize;
        let mut inter = 0usize;
        for u in 0..g.n() as Vertex {
            for &v in g.neighbors(u) {
                if v > u {
                    if planted_community(u, 25) == planted_community(v, 25) {
                        intra += 1;
                    } else {
                        inter += 1;
                    }
                }
            }
        }
        assert!(intra > 4 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn planted_valid() {
        planted_partition(5, 10, 0.6, 0.05, 11).validate();
    }
}
