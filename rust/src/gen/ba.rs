//! Barabási–Albert preferential attachment — power-law degrees with
//! moderate clustering; a second social-network-like family.

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::util::Rng;

/// BA model: start from a clique on `m0 = k` vertices, then each new
/// vertex attaches to `k` existing vertices chosen proportionally to
/// degree (implemented with the repeated-endpoint trick: sampling a
/// uniform endpoint from the running edge list is degree-proportional).
pub fn barabasi_albert(n: usize, k: usize, seed: u64) -> Graph {
    assert!(k >= 1 && n > k, "need n > k >= 1");
    let mut rng = Rng::new(seed);
    // endpoint pool: every edge contributes both endpoints
    let mut pool: Vec<Vertex> = Vec::with_capacity(2 * n * k);
    let mut edges: Vec<(Vertex, Vertex)> = Vec::with_capacity(n * k);
    // seed clique on k+1 vertices
    for u in 0..=k {
        for v in (u + 1)..=k {
            edges.push((u as Vertex, v as Vertex));
            pool.push(u as Vertex);
            pool.push(v as Vertex);
        }
    }
    for u in (k + 1)..n {
        let mut targets = Vec::with_capacity(k);
        while targets.len() < k {
            let t = pool[rng.range(0, pool.len())];
            if t != u as Vertex && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            edges.push((u as Vertex, t));
            pool.push(u as Vertex);
            pool.push(t);
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ba_deterministic() {
        assert_eq!(barabasi_albert(200, 3, 4), barabasi_albert(200, 3, 4));
    }

    #[test]
    fn ba_edge_count() {
        let n = 300;
        let k = 4;
        let g = barabasi_albert(n, k, 1);
        // clique edges + k per added vertex (dedup can only remove a few)
        let expected = k * (k + 1) / 2 + (n - k - 1) * k;
        assert_eq!(g.m(), expected);
    }

    #[test]
    fn ba_hub_emerges() {
        let g = barabasi_albert(500, 2, 9);
        // preferential attachment → max degree well above k
        assert!(g.max_degree() > 20, "dmax={}", g.max_degree());
    }

    #[test]
    fn ba_valid_and_connected() {
        let g = barabasi_albert(128, 3, 2);
        g.validate();
        let (_, ncomp) = g.components();
        assert_eq!(ncomp, 1);
    }
}
