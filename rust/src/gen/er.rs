//! Erdős–Rényi G(n, p) generator (low clustering — the "hard" end for
//! triangle-based work estimates, high wedge/triangle ratio like
//! as-skitter in Table 1).

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::util::Rng;

/// Sample G(n, p) using geometric skipping (Batagelj–Brandes), O(n + m).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    assert!((0.0..=1.0).contains(&p));
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    if p > 0.0 && n > 1 {
        if p >= 1.0 {
            return super::complete(n);
        }
        let lq = (1.0 - p).ln();
        let (mut v, mut w): (i64, i64) = (1, -1);
        while (v as usize) < n {
            let r = 1.0 - rng.f64(); // (0, 1]
            w += 1 + (r.ln() / lq).floor() as i64;
            while w >= v && (v as usize) < n {
                w -= v;
                v += 1;
            }
            if (v as usize) < n {
                edges.push((w as Vertex, v as Vertex));
            }
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn er_deterministic() {
        let a = erdos_renyi(100, 0.1, 5);
        let b = erdos_renyi(100, 0.1, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn er_seed_changes_graph() {
        let a = erdos_renyi(100, 0.1, 5);
        let b = erdos_renyi(100, 0.1, 6);
        assert_ne!(a, b);
    }

    #[test]
    fn er_density_close_to_p() {
        let n = 400;
        let p = 0.05;
        let g = erdos_renyi(n, p, 11);
        let expected = p * (n * (n - 1) / 2) as f64;
        let got = g.m() as f64;
        assert!(
            (got - expected).abs() < 0.15 * expected,
            "m={got} expected≈{expected}"
        );
    }

    #[test]
    fn er_extremes() {
        assert_eq!(erdos_renyi(50, 0.0, 1).m(), 0);
        assert_eq!(erdos_renyi(10, 1.0, 1).m(), 45);
        assert_eq!(erdos_renyi(1, 0.5, 1).m(), 0);
    }

    #[test]
    fn er_always_valid() {
        forall("er-valid", 16, |rng| {
            let n = rng.range(1, 80);
            let p = rng.f64();
            erdos_renyi(n, p, rng.next_u64()).validate();
        });
    }
}
