//! Synthetic graph generators.
//!
//! The paper evaluates on 15 SNAP/UF graphs (social networks and web
//! crawls). Those datasets are not available in this environment, so the
//! test suite is generated synthetically with the same controllable
//! structure the paper's analysis keys on: degree skew (RMAT), clustering
//! (planted partition / Watts–Strogatz), and scale. See DESIGN.md §2.

mod basic;
mod ba;
mod er;
mod planted;
mod rmat;
mod suite;
mod ws;

pub use ba::barabasi_albert;
pub use basic::{complete, ring, star, grid2d, path};
pub use er::erdos_renyi;
pub use planted::{planted_community, planted_partition};
pub use rmat::rmat;
pub use suite::{suite, suite_by_name, SuiteGraph, SUITE_NAMES};
pub use ws::watts_strogatz;
