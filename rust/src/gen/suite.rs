//! The benchmark graph suite — synthetic analogues of the paper's
//! Table 1 instances, scaled to this testbed (see DESIGN.md §2).
//!
//! Family mapping:
//! - social networks (soc-pokec, soc-LiveJournal1, com-orkut, ...) →
//!   RMAT with skewed quadrants + BA;
//! - web crawls (in-2004, uk-2002, indochina-2004, ...) →
//!   planted-partition (high clustering, high t_max) + Watts–Strogatz;
//! - as-skitter (extreme wedge/triangle ratio) → star-heavy RMAT;
//! - cit-Patents (low clustering citation net) → sparse ER + BA mix.

use super::*;
use crate::graph::Graph;

/// A named suite instance: the graph plus the family tag used in
/// EXPERIMENTS.md analyses.
pub struct SuiteGraph {
    pub name: &'static str,
    pub family: &'static str,
    pub graph: Graph,
}

/// Construct one suite graph by name. `scale` multiplies the base size
/// (1 = default benchmark size for this box).
pub fn suite_by_name(name: &str, scale: usize) -> Option<SuiteGraph> {
    let s = scale.max(1);
    let g = |name: &'static str, family: &'static str, graph: Graph| {
        Some(SuiteGraph { name, family, graph })
    };
    match name {
        // citation-network analogue: sparse, moderate clustering
        "cit-pat" => g("cit-pat", "citation", {
            let a = erdos_renyi(8_000 * s, 3.2 / (8_000.0 * s as f64), 101);
            let b = barabasi_albert(8_000 * s, 3, 102);
            merge(a, b)
        }),
        // social-network analogues: skewed RMAT
        "soc-rmat-s" => g("soc-rmat-s", "social", rmat(8_192 * s, 40_000 * s, 0.57, 0.19, 0.19, 201)),
        "soc-rmat-m" => g("soc-rmat-m", "social", rmat(16_384 * s, 100_000 * s, 0.57, 0.19, 0.19, 202)),
        "soc-ba" => g("soc-ba", "social", barabasi_albert(20_000 * s, 8, 203)),
        // skitter analogue: extreme hub skew → huge wedge/triangle ratio
        "skitter-like" => g("skitter-like", "internet", rmat(16_384 * s, 60_000 * s, 0.70, 0.14, 0.14, 301)),
        // web-crawl analogues: high clustering, high trussness
        "web-pp-s" => g("web-pp-s", "web", planted_partition(160 * s, 24, 0.72, 0.0008, 401)),
        "web-pp-m" => g("web-pp-m", "web", planted_partition(320 * s, 28, 0.65, 0.0006, 402)),
        "web-ws" => g("web-ws", "web", watts_strogatz(24_000 * s, 6, 0.08, 403)),
        // hollywood analogue: overlapping dense cliques
        "holly-like" => g("holly-like", "collab", {
            let a = planted_partition(120 * s, 32, 0.85, 0.001, 501);
            let b = rmat(4_096 * s, 30_000 * s, 0.55, 0.2, 0.2, 502);
            merge(a, b)
        }),
        // uniform random: low clustering baseline
        "er-sparse" => g("er-sparse", "random", erdos_renyi(30_000 * s, 8.0 / 30_000.0, 601)),
        _ => None,
    }
}

/// All suite names in the canonical (wedge-ordered, like Table 1) order.
pub const SUITE_NAMES: [&str; 10] = [
    "cit-pat",
    "web-pp-s",
    "er-sparse",
    "web-ws",
    "web-pp-m",
    "soc-ba",
    "soc-rmat-s",
    "holly-like",
    "skitter-like",
    "soc-rmat-m",
];

/// Build the full suite at the given scale.
pub fn suite(scale: usize) -> Vec<SuiteGraph> {
    SUITE_NAMES
        .iter()
        .map(|n| suite_by_name(n, scale).expect("suite name"))
        .collect()
}

/// Union of two graphs on max(n_a, n_b) vertices.
fn merge(a: Graph, b: Graph) -> Graph {
    use crate::graph::{GraphBuilder, Vertex};
    let n = a.n().max(b.n());
    let mut edges = Vec::with_capacity(a.m() + b.m());
    for g in [&a, &b] {
        for u in 0..g.n() as Vertex {
            for &v in g.neighbors(u) {
                if v > u {
                    edges.push((u, v));
                }
            }
        }
    }
    GraphBuilder::new().num_vertices(n).edges_vec(edges).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_suite_names_resolve() {
        for name in SUITE_NAMES {
            let sg = suite_by_name(name, 1).expect("resolves");
            assert_eq!(sg.name, name);
            assert!(sg.graph.m() > 1000, "{name} too small: m={}", sg.graph.m());
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(suite_by_name("nope", 1).is_none());
    }

    #[test]
    fn suite_is_deterministic() {
        let a = suite_by_name("web-pp-s", 1).unwrap();
        let b = suite_by_name("web-pp-s", 1).unwrap();
        assert_eq!(a.graph, b.graph);
    }

    #[test]
    fn merge_unions_edges() {
        let a = complete(4);
        let b = ring(6);
        let u = merge(a, b);
        assert_eq!(u.n(), 6);
        assert!(u.has_edge(0, 3)); // from K4
        assert!(u.has_edge(4, 5)); // from ring
    }
}
