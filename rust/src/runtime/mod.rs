//! PJRT runtime — loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and executes them from the Rust hot path.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Python never runs at request time — the
//! binary is self-contained once `artifacts/` exists.

mod artifacts;

pub use artifacts::{default_artifacts_dir, Manifest};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled-executable registry over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, executables: HashMap::new() })
    }

    /// Platform string (e.g. "cpu") — surfaced in server status.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and register it under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every artifact listed in `<dir>/manifest.txt` (written by
    /// aot.py: one `name<TAB>filename` per line).
    pub fn load_manifest(&mut self, dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let manifest = Manifest::read(dir)?;
        for (name, file) in &manifest.entries {
            self.load_hlo_text(name, dir.join(file))?;
        }
        Ok(manifest)
    }

    /// Names of loaded executables.
    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute `name` with the given input literals; returns the output
    /// tuple elements (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("no artifact named '{name}' loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))
    }

    /// Convenience: execute and return each output as an `f32` vector.
    pub fn execute_f32(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.execute(name, inputs)?
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Build an `f32[rows*cols]` literal with the given shape.
pub fn literal_matrix(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Scalar f32 literal.
pub fn literal_scalar(x: f32) -> xla::Literal {
    xla::Literal::from(x)
}

/// Locate the artifacts directory: `$TRUSSX_ARTIFACTS`, else
/// `./artifacts` relative to the current dir, else next to the binary.
pub fn artifacts_dir() -> PathBuf {
    default_artifacts_dir()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need compiled artifacts live in
    // rust/tests/xla_integration.rs (they require `make artifacts`).
    // Here: client creation and error paths only.

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.names().is_empty());
    }

    #[test]
    fn missing_executable_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.execute("nope", &[]).is_err());
        assert!(!rt.has("nope"));
    }

    #[test]
    fn missing_artifact_file_errors() {
        let mut rt = Runtime::cpu().unwrap();
        assert!(rt.load_hlo_text("x", "/nonexistent/path.hlo.txt").is_err());
    }

    #[test]
    fn literal_matrix_shape() {
        let l = literal_matrix(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(l.element_count(), 4);
    }
}
