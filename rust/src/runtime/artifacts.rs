//! Artifact manifest handling.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one
//! `name<TAB>filename` line per lowered program (e.g.
//! `support_64<TAB>support_64.hlo.txt`). The Rust side loads programs by
//! manifest name so the set of block sizes is decided at compile time by
//! Python and discovered at run time by Rust.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// `(name, filename)` pairs in manifest order.
    pub entries: Vec<(String, String)>,
}

impl Manifest {
    /// Read `<dir>/manifest.txt`.
    pub fn read(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((name, file)) = line.split_once('\t') else {
                bail!("manifest line {} not name<TAB>file: {line:?}", i + 1);
            };
            entries.push((name.trim().to_string(), file.trim().to_string()));
        }
        Ok(Self { entries })
    }

    /// Names of all `support_<B>` programs, with their block sizes,
    /// sorted ascending by block size.
    pub fn support_blocks(&self) -> Vec<usize> {
        let mut blocks: Vec<usize> = self
            .entries
            .iter()
            .filter_map(|(n, _)| n.strip_prefix("support_").and_then(|b| b.parse().ok()))
            .collect();
        blocks.sort_unstable();
        blocks
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.iter().any(|(n, _)| n == name)
    }
}

/// Locate the artifacts directory: `$TRUSSX_ARTIFACTS` wins; otherwise
/// walk up from the current directory looking for `artifacts/manifest.txt`
/// (so tests and examples work from any workspace subdirectory).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("TRUSSX_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse("# comment\nsupport_64\tsupport_64.hlo.txt\npeel_64\tpeel_64.hlo.txt\nsupport_128\tsupport_128.hlo.txt\n").unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.support_blocks(), vec![64, 128]);
        assert!(m.has("peel_64"));
        assert!(!m.has("peel_256"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Manifest::parse("no-tab-here\n").is_err());
    }

    #[test]
    fn parse_empty_ok() {
        let m = Manifest::parse("").unwrap();
        assert!(m.entries.is_empty());
        assert!(m.support_blocks().is_empty());
    }
}
