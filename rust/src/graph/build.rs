//! Graph builder: raw edge tuples → clean CSR.
//!
//! Mirrors the paper's preprocessing: directed inputs are made
//! undirected, self loops and duplicate edges are removed.

use super::{Graph, Vertex};

/// Accumulates raw (possibly directed / duplicated / self-looped) edge
/// tuples and produces a clean, sorted, symmetric CSR [`Graph`].
#[derive(Default)]
pub struct GraphBuilder {
    edges: Vec<(Vertex, Vertex)>,
    min_n: usize,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure the graph has at least `n` vertices (for isolated tails).
    pub fn num_vertices(mut self, n: usize) -> Self {
        self.min_n = self.min_n.max(n);
        self
    }

    /// Add a batch of edges.
    pub fn edges(mut self, es: &[(Vertex, Vertex)]) -> Self {
        self.edges.extend_from_slice(es);
        self
    }

    /// Add one edge.
    pub fn edge(mut self, u: Vertex, v: Vertex) -> Self {
        self.edges.push((u, v));
        self
    }

    /// Take ownership of an edge vector (avoids a copy for generators).
    pub fn edges_vec(mut self, mut es: Vec<(Vertex, Vertex)>) -> Self {
        if self.edges.is_empty() {
            self.edges = std::mem::take(&mut es);
        } else {
            self.edges.append(&mut es);
        }
        self
    }

    /// Build the CSR graph: undirect, drop self loops, dedup, sort.
    pub fn build(self) -> Graph {
        let GraphBuilder { edges, min_n } = self;
        // Canonicalize to u < v, dropping self loops.
        let mut canon: Vec<(Vertex, Vertex)> = edges
            .into_iter()
            .filter(|&(u, v)| u != v)
            .map(|(u, v)| if u < v { (u, v) } else { (v, u) })
            .collect();
        canon.sort_unstable();
        canon.dedup();

        let n = canon
            .iter()
            .map(|&(_, v)| v as usize + 1)
            .max()
            .unwrap_or(0)
            .max(min_n);

        // Counting pass for degrees, then fill.
        let mut deg = vec![0usize; n];
        for &(u, v) in &canon {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut xadj = vec![0usize; n + 1];
        for u in 0..n {
            xadj[u + 1] = xadj[u] + deg[u];
        }
        let mut cursor = xadj[..n].to_vec();
        let mut adj = vec![0 as Vertex; xadj[n]];
        for &(u, v) in &canon {
            adj[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        // canon is sorted by (u,v); pushing in that order leaves each
        // row's "greater neighbor" suffix sorted, but the "smaller
        // neighbor" prefix arrives in increasing u order too — rows are
        // already sorted. Sort anyway defensively (cheap, one pass).
        for u in 0..n {
            adj[xadj[u]..xadj[u + 1]].sort_unstable();
        }
        Graph::from_csr(xadj, adj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn dedup_and_self_loops() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 0), (0, 1), (2, 2), (1, 2)])
            .build();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2); // <0,1> and <1,2>
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 2));
        assert!(!g.has_edge(2, 2));
    }

    #[test]
    fn directed_input_symmetrized() {
        let g = GraphBuilder::new().edges(&[(3, 1)]).build();
        assert!(g.has_edge(1, 3));
        assert!(g.has_edge(3, 1));
        assert_eq!(g.n(), 4);
    }

    #[test]
    fn empty_build() {
        let g = GraphBuilder::new().build();
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn builder_random_edges_always_valid() {
        forall("builder-valid", 32, |rng| {
            let n = rng.range(1, 40);
            let k = rng.range(0, 120);
            let mut es = Vec::with_capacity(k);
            for _ in 0..k {
                es.push((rng.below(n as u64) as u32, rng.below(n as u64) as u32));
            }
            let g = GraphBuilder::new().num_vertices(n).edges_vec(es).build();
            g.validate(); // full invariant check
            assert_eq!(g.n(), n.max(g.n()));
        });
    }
}
