//! Graph file I/O: whitespace edge lists (SNAP style), MatrixMarket
//! pattern files (UF collection style), and a fast binary CSR format.

use super::{Graph, GraphBuilder, Vertex};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Magic header for the binary CSR format.
const BIN_MAGIC: &[u8; 8] = b"TRUSSX01";

/// Parse a SNAP-style edge list: one `u v` pair per line, `#` or `%`
/// comment lines ignored. Directed inputs are symmetrized; self loops and
/// duplicates dropped (the paper's preprocessing).
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut edges = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: Vertex = it
            .next()
            .context("missing source vertex")?
            .parse()
            .with_context(|| format!("bad source on line {}", lineno + 1))?;
        let v: Vertex = it
            .next()
            .context("missing target vertex")?
            .parse()
            .with_context(|| format!("bad target on line {}", lineno + 1))?;
        edges.push((u, v));
    }
    Ok(GraphBuilder::new().edges_vec(edges).build())
}

/// Read an edge-list file.
pub fn read_edge_list(path: impl AsRef<Path>) -> Result<Graph> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_edge_list(&text)
}

/// Write a canonical (u < v) edge list.
pub fn write_edge_list(g: &Graph, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# trussx edge list: n={} m={}", g.n(), g.m())?;
    for u in 0..g.n() as Vertex {
        for &v in g.neighbors(u) {
            if v > u {
                writeln!(w, "{u} {v}")?;
            }
        }
    }
    Ok(())
}

/// Parse a MatrixMarket coordinate file (pattern or weighted; weights are
/// ignored). 1-based indices per the MM spec.
pub fn parse_matrix_market(text: &str) -> Result<Graph> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty MatrixMarket file")?;
    if !header.starts_with("%%MatrixMarket") {
        bail!("not a MatrixMarket file (missing %%MatrixMarket header)");
    }
    if !header.contains("coordinate") {
        bail!("only coordinate MatrixMarket supported");
    }
    let mut size_seen = false;
    let mut edges = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        if !size_seen {
            // rows cols nnz — validated loosely; we derive n from entries.
            let _rows: usize = it.next().context("bad size line")?.parse()?;
            let _cols: usize = it.next().context("bad size line")?.parse()?;
            let _nnz: usize = it.next().context("bad size line")?.parse()?;
            size_seen = true;
            continue;
        }
        let u: u64 = it.next().context("missing row index")?.parse()?;
        let v: u64 = it.next().context("missing col index")?.parse()?;
        if u == 0 || v == 0 {
            bail!("MatrixMarket indices are 1-based; found 0");
        }
        edges.push(((u - 1) as Vertex, (v - 1) as Vertex));
    }
    Ok(GraphBuilder::new().edges_vec(edges).build())
}

/// Read a MatrixMarket file.
pub fn read_matrix_market(path: impl AsRef<Path>) -> Result<Graph> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_matrix_market(&text)
}

/// Write binary CSR: magic, n, 2m, xadj (u64 LE), adj (u32 LE).
pub fn write_binary(g: &Graph, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.adj.len() as u64).to_le_bytes())?;
    for &x in &g.xadj {
        w.write_all(&(x as u64).to_le_bytes())?;
    }
    for &v in &g.adj {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read binary CSR written by [`write_binary`].
pub fn read_binary(path: impl AsRef<Path>) -> Result<Graph> {
    let f = std::fs::File::open(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        bail!("bad magic: not a trussx binary graph");
    }
    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    let nadj = u64::from_le_bytes(buf8) as usize;
    let mut xadj = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        r.read_exact(&mut buf8)?;
        xadj.push(u64::from_le_bytes(buf8) as usize);
    }
    let mut adj = Vec::with_capacity(nadj);
    let mut buf4 = [0u8; 4];
    for _ in 0..nadj {
        r.read_exact(&mut buf4)?;
        adj.push(u32::from_le_bytes(buf4));
    }
    Ok(Graph::from_csr(xadj, adj))
}

/// Load a graph by extension: `.el`/`.txt`/`.edges` → edge list,
/// `.mtx` → MatrixMarket, `.bin` → binary CSR.
pub fn read_auto(path: impl AsRef<Path>) -> Result<Graph> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some("mtx") => read_matrix_market(p),
        Some("bin") => read_binary(p),
        _ => read_edge_list(p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_list_roundtrip() {
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2), (0, 2), (2, 3)]).build();
        let dir = std::env::temp_dir().join("trussx_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.el");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn binary_roundtrip() {
        let g = crate::gen::rmat(128, 512, 0.57, 0.19, 0.19, 7);
        let dir = std::env::temp_dir().join("trussx_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn edge_list_comments_and_dups() {
        let g = parse_edge_list("# comment\n% also comment\n0 1\n1 0\n1 1\n1 2\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn edge_list_malformed_rejected() {
        assert!(parse_edge_list("0 x\n").is_err());
        assert!(parse_edge_list("0\n").is_err());
        assert!(parse_edge_list("-1 2\n").is_err());
    }

    #[test]
    fn matrix_market_parse() {
        let mm = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                  % UF-style comment\n\
                  3 3 3\n1 2\n2 3\n1 3\n";
        let g = parse_matrix_market(mm).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert!(g.has_edge(0, 2));
    }

    #[test]
    fn matrix_market_weighted_ok() {
        let mm = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 2 3.5\n";
        let g = parse_matrix_market(mm).unwrap();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn matrix_market_rejects_bad_header() {
        assert!(parse_matrix_market("not a matrix\n1 1 0\n").is_err());
        assert!(parse_matrix_market("%%MatrixMarket matrix array real\n").is_err());
        // 0-based index is invalid
        let mm = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n0 1\n";
        assert!(parse_matrix_market(mm).is_err());
    }

    #[test]
    fn binary_bad_magic_rejected() {
        let dir = std::env::temp_dir().join("trussx_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC0000000000000000").unwrap();
        assert!(read_binary(&p).is_err());
    }
}
