//! Compressed sparse row (CSR) storage for a simple undirected graph.

use super::Vertex;

/// A simple undirected graph in CSR form.
///
/// Invariants (checked by `debug_validate`, relied upon everywhere):
/// - `xadj.len() == n + 1`, `xadj[0] == 0`, `xadj[n] == adj.len() == 2m`;
/// - each adjacency list `adj[xadj[u]..xadj[u+1]]` is strictly increasing
///   (sorted, no duplicates, no self loops);
/// - symmetry: `v ∈ N(u) ⇔ u ∈ N(v)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Graph {
    /// Row offsets, length n+1.
    pub xadj: Vec<usize>,
    /// Concatenated sorted adjacency lists, length 2m.
    pub adj: Vec<Vertex>,
}

impl Graph {
    /// Construct from raw CSR arrays. Panics if the shape invariants are
    /// violated (full symmetry checking is in `debug_validate`).
    pub fn from_csr(xadj: Vec<usize>, adj: Vec<Vertex>) -> Self {
        assert!(!xadj.is_empty(), "xadj must have length n+1 >= 1");
        assert_eq!(xadj[0], 0);
        assert_eq!(*xadj.last().unwrap(), adj.len());
        let g = Self { xadj, adj };
        g.debug_validate();
        g
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.xadj.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.adj.len() / 2
    }

    /// Neighbors of `u`, sorted ascending.
    #[inline]
    pub fn neighbors(&self, u: Vertex) -> &[Vertex] {
        &self.adj[self.xadj[u as usize]..self.xadj[u as usize + 1]]
    }

    /// Degree of `u`.
    #[inline]
    pub fn degree(&self, u: Vertex) -> usize {
        self.xadj[u as usize + 1] - self.xadj[u as usize]
    }

    /// Maximum degree over all vertices (0 for an empty graph).
    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|u| self.degree(u as Vertex)).max().unwrap_or(0)
    }

    /// Binary-search membership test: is `<u, v>` an edge?
    #[inline]
    pub fn has_edge(&self, u: Vertex, v: Vertex) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Σ_v d(v)² — the work estimate for ordering-oblivious wedge
    /// enumeration (Table 2, col Σd(v)²).
    pub fn sum_deg_sq(&self) -> u64 {
        (0..self.n())
            .map(|u| {
                let d = self.degree(u as Vertex) as u64;
                d * d
            })
            .sum()
    }

    /// Number of wedges `|∧| = Σ_v d(v)·(d(v)−1)/2` — the paper's primary
    /// work measure (Table 1 orders graphs by it; GWeps divides by it).
    pub fn wedge_count(&self) -> u64 {
        (0..self.n())
            .map(|u| {
                let d = self.degree(u as Vertex) as u64;
                d * (d - 1) / 2
            })
            .sum()
    }

    /// Σ_v d⁺(v)² under the *current* vertex numbering, where
    /// `d⁺(v) = |{w ∈ N(v) : w > v}|` — the ordering-aware triangle
    /// counting work estimate (Table 2, cols Σd⁺(v)² KCO/NAT).
    pub fn sum_deg_plus_sq(&self) -> u64 {
        (0..self.n())
            .map(|u| {
                let nu = self.neighbors(u as Vertex);
                let split = nu.partition_point(|&w| w <= u as Vertex);
                let dp = (nu.len() - split) as u64;
                dp * dp
            })
            .sum()
    }

    /// Expensive O(m log d) structural validation; debug builds only by
    /// default, also invoked explicitly from tests.
    pub fn debug_validate(&self) {
        #[cfg(debug_assertions)]
        self.validate();
    }

    /// Full invariant check (sortedness, no self loops/dups, symmetry).
    pub fn validate(&self) {
        let n = self.n();
        for u in 0..n {
            assert!(self.xadj[u] <= self.xadj[u + 1], "xadj not monotone at {u}");
            let nu = self.neighbors(u as Vertex);
            for w in nu.windows(2) {
                assert!(w[0] < w[1], "adjacency of {u} not strictly increasing");
            }
            for &v in nu {
                assert!((v as usize) < n, "neighbor {v} out of range");
                assert_ne!(v as usize, u, "self loop at {u}");
                assert!(
                    self.neighbors(v).binary_search(&(u as Vertex)).is_ok(),
                    "asymmetric edge <{u},{v}>"
                );
            }
        }
    }

    /// Connected components by BFS; returns (component id per vertex,
    /// number of components). Used for k-truss subgraph extraction.
    pub fn components(&self) -> (Vec<u32>, usize) {
        let n = self.n();
        let mut comp = vec![u32::MAX; n];
        let mut next_comp = 0u32;
        let mut queue = std::collections::VecDeque::new();
        for s in 0..n {
            if comp[s] != u32::MAX {
                continue;
            }
            comp[s] = next_comp;
            queue.push_back(s as Vertex);
            while let Some(u) = queue.pop_front() {
                for &v in self.neighbors(u) {
                    if comp[v as usize] == u32::MAX {
                        comp[v as usize] = next_comp;
                        queue.push_back(v);
                    }
                }
            }
            next_comp += 1;
        }
        (comp, next_comp as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn triangle() -> Graph {
        GraphBuilder::new().edges(&[(0, 1), (1, 2), (0, 2)]).build()
    }

    #[test]
    fn basic_counts() {
        let g = triangle();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.wedge_count(), 3);
        assert_eq!(g.sum_deg_sq(), 12);
    }

    #[test]
    fn neighbors_sorted() {
        let g = GraphBuilder::new().edges(&[(2, 0), (2, 1), (2, 3)]).build();
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn has_edge_both_directions() {
        let g = triangle();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn deg_plus_sq_path() {
        // path 0-1-2: d+(0)=1, d+(1)=1, d+(2)=0 → 1+1+0 = 2
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2)]).build();
        assert_eq!(g.sum_deg_plus_sq(), 2);
    }

    #[test]
    fn components_two_triangles() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)])
            .build();
        let (comp, k) = g.components();
        assert_eq!(k, 2);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[0], comp[2]);
        assert_eq!(comp[3], comp[4]);
        assert_ne!(comp[0], comp[3]);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_csr(vec![0], vec![]);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
        assert_eq!(g.wedge_count(), 0);
        let (_, k) = g.components();
        assert_eq!(k, 0);
    }

    #[test]
    fn isolated_vertices() {
        let g = GraphBuilder::new().num_vertices(5).edges(&[(0, 1)]).build();
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 1);
        assert_eq!(g.degree(4), 0);
        let (_, k) = g.components();
        assert_eq!(k, 4);
    }

    #[test]
    #[should_panic]
    fn bad_csr_rejected() {
        // xadj end doesn't match adj length
        let _ = Graph::from_csr(vec![0, 2], vec![1]);
    }
}
