//! Graph substrate: CSR storage, the truss-augmented edge representation
//! from Fig. 2 of the paper, builders, and file I/O.

mod build;
mod compact;
mod csr;
mod edge;
pub mod io;

pub use build::GraphBuilder;
pub use compact::{compact_edges, EdgeCompaction};
pub use csr::Graph;
pub use edge::EdgeGraph;

/// Vertex id. Graphs in this reproduction are capped well below 2^32
/// vertices, matching the paper's 4-byte-integer space accounting
/// (28m + 8n bytes for the truss representation).
pub type Vertex = u32;

/// Edge id in `[0, m)`. Each undirected edge has exactly one id.
pub type EdgeId = u32;
