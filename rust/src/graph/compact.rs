//! Active-graph compaction: rebuild a relabeled sub-[`EdgeGraph`] on the
//! surviving edges of a partially peeled graph.
//!
//! The peel re-scans all `m` edges per level and enumerates triangles
//! through adjacency lists that still contain long-dead edges; once the
//! live fraction is small that is almost pure wasted bandwidth. Wang &
//! Cheng (1205.6693) scale truss decomposition past memory limits by
//! iteratively shrinking the graph, and Jakkula & Karypis (1908.10550)
//! re-decompose over a compacted edge set; this module is that idea for
//! the shared-memory peel.
//!
//! Key invariant exploited here: [`EdgeGraph::new`] assigns edge ids in
//! lexicographic `(u, v)` order of the canonical edges. Surviving old
//! ids taken in ascending order therefore *are* the lexicographic order
//! a rebuild would assign, so `old_of_new` is simply the sorted survivor
//! list and the peel's lower-edge-id triangle-ownership rule stays
//! consistent across the relabeling. Vertices are not renumbered (the
//! peel's per-thread marking arrays and `el` endpoints stay valid).

use super::{EdgeGraph, EdgeId, Graph, Vertex};
use crate::par::Pool;
use std::sync::Mutex;

/// A compacted sub-graph plus the old↔new edge-id mapping.
pub struct EdgeCompaction {
    /// The relabeled sub-graph on the surviving edges (same vertex set).
    pub eg: EdgeGraph,
    /// `old_of_new[new] = old`: strictly increasing, so the inverse map
    /// is a binary search.
    pub old_of_new: Vec<EdgeId>,
}

impl EdgeCompaction {
    /// Old id of a compacted edge.
    #[inline]
    pub fn old_id(&self, new: EdgeId) -> EdgeId {
        self.old_of_new[new as usize]
    }

    /// New id of a surviving old edge, `None` if it was dropped.
    pub fn new_id(&self, old: EdgeId) -> Option<EdgeId> {
        self.old_of_new.binary_search(&old).ok().map(|i| i as EdgeId)
    }
}

/// Build the sub-[`EdgeGraph`] on the edges where `alive` holds.
///
/// The survivor gather is parallel (contiguous static slabs per thread,
/// concatenated in tid order so old ids stay ascending); the CSR fill is
/// a serial O(m') pass over the survivors, which the caller only pays
/// when `m'` is already a small fraction of `m`. The fill needs no row
/// sorting: survivors are processed in lexicographic `(u, v)` order, so
/// each row receives its lower neighbors in ascending order first, then
/// its upper neighbors in ascending order.
pub fn compact_edges<F>(eg: &EdgeGraph, pool: &Pool, alive: F) -> EdgeCompaction
where
    F: Fn(EdgeId) -> bool + Sync,
{
    let n = eg.n();
    let m = eg.m();

    let t = pool.nthreads();
    let parts: Vec<Mutex<Vec<EdgeId>>> = (0..t).map(|_| Mutex::new(Vec::new())).collect();
    pool.region(|ctx| {
        let (lo, hi) = ctx.static_range(m);
        let mut local = Vec::new();
        for e in lo..hi {
            if alive(e as EdgeId) {
                local.push(e as EdgeId);
            }
        }
        *parts[ctx.tid].lock().unwrap() = local;
    });
    let mut old_of_new: Vec<EdgeId> = Vec::new();
    for p in &parts {
        old_of_new.append(&mut p.lock().unwrap());
    }
    debug_assert!(old_of_new.windows(2).all(|w| w[0] < w[1]));

    let new_m = old_of_new.len();
    // per-vertex degree and lower-neighbor counts in the sub-graph
    let mut deg = vec![0usize; n];
    let mut lower = vec![0usize; n];
    for &o in &old_of_new {
        let (u, v) = eg.el[o as usize];
        deg[u as usize] += 1;
        deg[v as usize] += 1;
        lower[v as usize] += 1;
    }
    let mut xadj = vec![0usize; n + 1];
    for u in 0..n {
        xadj[u + 1] = xadj[u] + deg[u];
    }
    // row u: [xadj[u], eo[u]) holds neighbors < u, [eo[u], xadj[u+1])
    // holds neighbors > u — the same split EdgeGraph::new derives
    let eo: Vec<usize> = (0..n).map(|u| xadj[u] + lower[u]).collect();
    let mut cur_lo: Vec<usize> = xadj[..n].to_vec();
    let mut cur_hi = eo.clone();
    let mut adj = vec![0 as Vertex; 2 * new_m];
    let mut eid = vec![0 as EdgeId; 2 * new_m];
    let mut el = Vec::with_capacity(new_m);
    for (new, &o) in old_of_new.iter().enumerate() {
        let (u, v) = eg.el[o as usize];
        el.push((u, v));
        adj[cur_hi[u as usize]] = v;
        eid[cur_hi[u as usize]] = new as EdgeId;
        cur_hi[u as usize] += 1;
        adj[cur_lo[v as usize]] = u;
        eid[cur_lo[v as usize]] = new as EdgeId;
        cur_lo[v as usize] += 1;
    }
    debug_assert!(el.windows(2).all(|w| w[0] < w[1]), "survivors must stay lex-ordered");

    let g = Graph::from_csr(xadj, adj);
    EdgeCompaction { eg: EdgeGraph { g, eid, eo, el }, old_of_new }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::util::forall;

    /// Reference: rebuild from scratch through the constructors.
    fn rebuild_reference(eg: &EdgeGraph, keep: &[EdgeId]) -> EdgeGraph {
        let edges: Vec<(Vertex, Vertex)> =
            keep.iter().map(|&o| eg.el[o as usize]).collect();
        let g = GraphBuilder::new().num_vertices(eg.n()).edges_vec(edges).build();
        EdgeGraph::new(g)
    }

    fn assert_same(a: &EdgeGraph, b: &EdgeGraph) {
        assert_eq!(a.g.xadj, b.g.xadj);
        assert_eq!(a.g.adj, b.g.adj);
        assert_eq!(a.eid, b.eid);
        assert_eq!(a.eo, b.eo);
        assert_eq!(a.el, b.el);
    }

    #[test]
    fn identity_compaction_reproduces_graph() {
        let g = gen::planted_partition(3, 10, 0.8, 0.05, 11);
        let eg = EdgeGraph::new(g);
        let c = compact_edges(&eg, &Pool::new(3), |_| true);
        assert_eq!(c.old_of_new, (0..eg.m() as EdgeId).collect::<Vec<_>>());
        c.eg.validate();
        assert_same(&c.eg, &eg);
    }

    #[test]
    fn subset_mask_small_graph() {
        // K4 plus a pendant: drop the pendant and one K4 edge
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4)])
            .build();
        let eg = EdgeGraph::new(g);
        let e03 = eg.edge_id(0, 3).unwrap();
        let e34 = eg.edge_id(3, 4).unwrap();
        let c = compact_edges(&eg, &Pool::new(2), |e| e != e03 && e != e34);
        assert_eq!(c.eg.m(), 5);
        assert_eq!(c.eg.n(), eg.n(), "vertex set is preserved");
        c.eg.validate();
        // mapping round-trips and dropped edges resolve to None
        for new in 0..c.eg.m() as EdgeId {
            let old = c.old_id(new);
            assert_eq!(c.new_id(old), Some(new));
            assert_eq!(c.eg.el[new as usize], eg.el[old as usize]);
        }
        assert_eq!(c.new_id(e03), None);
        assert_eq!(c.new_id(e34), None);
        assert_same(&c.eg, &rebuild_reference(&eg, &c.old_of_new));
    }

    #[test]
    fn empty_and_full_masks() {
        let eg = EdgeGraph::new(gen::complete(5));
        let none = compact_edges(&eg, &Pool::new(2), |_| false);
        assert_eq!(none.eg.m(), 0);
        assert_eq!(none.eg.n(), 5);
        none.eg.validate();
        let empty = EdgeGraph::new(GraphBuilder::new().build());
        let c = compact_edges(&empty, &Pool::new(2), |_| true);
        assert_eq!(c.eg.m(), 0);
        assert_eq!(c.eg.n(), 0);
    }

    #[test]
    fn random_masks_match_reference_rebuild() {
        forall("compact-matches-rebuild", 24, |rng| {
            let n = rng.range(2, 60);
            let g = gen::erdos_renyi(n, rng.f64() * 0.4, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let threads = rng.range(1, 5);
            // random mask with varying density
            let p = rng.f64();
            let mask: Vec<bool> = (0..eg.m()).map(|_| rng.f64() < p).collect();
            let c = compact_edges(&eg, &Pool::new(threads), |e| mask[e as usize]);
            c.eg.validate();
            assert_eq!(c.eg.m(), mask.iter().filter(|&&b| b).count());
            assert_same(&c.eg, &rebuild_reference(&eg, &c.old_of_new));
            for (new, &old) in c.old_of_new.iter().enumerate() {
                assert!(mask[old as usize]);
                assert_eq!(c.new_id(old), Some(new as EdgeId));
            }
        });
    }
}
