//! The truss-augmented graph representation (Fig. 2 of the paper).
//!
//! On top of CSR `(xadj, adj)` four arrays are kept:
//! - `eid` (len 2m): edge id for each adjacency slot, so both directed
//!   copies of an undirected edge share one id — this replaces the hash
//!   table used by WC;
//! - `eo`  (len n): for each vertex `u`, the absolute index in `adj` of
//!   the first neighbor greater than `u` (the `N⁺(u)` split point);
//! - `el`  (len m): the edge list — canonical `(u, v)` with `u < v`;
//! - support `S` lives *outside* this struct (algorithms own it).
//!
//! Space: with 4-byte ids this is the paper's 28m + 8n bytes.

use super::{EdgeId, Graph, Vertex};

/// CSR graph augmented with edge ids for truss computation.
#[derive(Clone, Debug)]
pub struct EdgeGraph {
    pub g: Graph,
    /// Edge id per adjacency slot (len 2m).
    pub eid: Vec<EdgeId>,
    /// Absolute index into `adj` of the first neighbor `> u` (len n).
    pub eo: Vec<usize>,
    /// Canonical edge list `(u, v)`, `u < v`, indexed by edge id (len m).
    pub el: Vec<(Vertex, Vertex)>,
}

impl EdgeGraph {
    /// Build the augmented representation. Edge ids are assigned in
    /// lexicographic `(u, v)` order of the canonical (u < v) edges, which
    /// also makes `eid` within each `N⁺(u)` range strictly increasing —
    /// a property the PKT ownership rule exploits.
    pub fn new(g: Graph) -> Self {
        let n = g.n();
        let m = g.m();
        let mut eid = vec![0 as EdgeId; g.adj.len()];
        let mut eo = vec![0usize; n];
        let mut el = Vec::with_capacity(m);

        // First pass: split points and id assignment for the u < v copies.
        let mut next_id: EdgeId = 0;
        for u in 0..n {
            let lo = g.xadj[u];
            let hi = g.xadj[u + 1];
            let row = &g.adj[lo..hi];
            let split = lo + row.partition_point(|&w| w < u as Vertex);
            eo[u] = split;
            for j in split..hi {
                eid[j] = next_id;
                el.push((u as Vertex, g.adj[j]));
                next_id += 1;
            }
        }
        debug_assert_eq!(next_id as usize, m);

        // Second pass: mirror ids onto the v > u copies (slots where the
        // neighbor is smaller than the row vertex). For row v, a slot
        // holding u < v gets the id of canonical edge (u, v), found by
        // binary search in u's upper row.
        for v in 0..n {
            let lo = g.xadj[v];
            for j in lo..eo[v] {
                let u = g.adj[j] as usize;
                // locate v within N⁺(u)
                let ulo = eo[u];
                let uhi = g.xadj[u + 1];
                let pos = g.adj[ulo..uhi]
                    .binary_search(&(v as Vertex))
                    .expect("symmetric edge missing");
                eid[j] = eid[ulo + pos];
            }
        }

        Self { g, eid, eo, el }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.g.n()
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.g.m()
    }

    /// The edge id of `<u, v>` if present.
    pub fn edge_id(&self, u: Vertex, v: Vertex) -> Option<EdgeId> {
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        let lo = self.eo[a as usize];
        let hi = self.g.xadj[a as usize + 1];
        self.g.adj[lo..hi]
            .binary_search(&b)
            .ok()
            .map(|pos| self.eid[lo + pos])
    }

    /// d⁺(u) — number of neighbors greater than u.
    #[inline]
    pub fn deg_plus(&self, u: Vertex) -> usize {
        self.g.xadj[u as usize + 1] - self.eo[u as usize]
    }

    /// Full invariant check for tests.
    pub fn validate(&self) {
        let n = self.n();
        let m = self.m();
        assert_eq!(self.eid.len(), self.g.adj.len());
        assert_eq!(self.eo.len(), n);
        assert_eq!(self.el.len(), m);
        let mut seen = vec![0u8; m];
        for u in 0..n {
            let (lo, hi) = (self.g.xadj[u], self.g.xadj[u + 1]);
            assert!((lo..=hi).contains(&self.eo[u]));
            for j in lo..hi {
                let v = self.g.adj[j];
                let e = self.eid[j] as usize;
                assert!(e < m, "eid out of range");
                let (a, b) = self.el[e];
                let (x, y) = if (u as Vertex) < v { (u as Vertex, v) } else { (v, u as Vertex) };
                assert_eq!((a, b), (x, y), "el mismatch for slot ({u},{v})");
                if j >= self.eo[u] {
                    assert!(v > u as Vertex);
                    seen[e] += 1;
                } else {
                    assert!(v < u as Vertex);
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "each edge must appear once in upper rows");
        for e in 0..m {
            let (u, v) = self.el[e];
            assert!(u < v);
            assert_eq!(self.edge_id(u, v), Some(e as EdgeId));
            assert_eq!(self.edge_id(v, u), Some(e as EdgeId));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::util::forall;

    #[test]
    fn fig2_style_small_graph() {
        // n=4, m=5: edges (0,1),(0,2),(0,3),(1,2),(2,3) — like paper Fig. 2
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (2, 3)])
            .build();
        let eg = EdgeGraph::new(g);
        eg.validate();
        assert_eq!(eg.m(), 5);
        // ids assigned lexicographically over canonical edges
        assert_eq!(eg.edge_id(0, 1), Some(0));
        assert_eq!(eg.edge_id(0, 2), Some(1));
        assert_eq!(eg.edge_id(0, 3), Some(2));
        assert_eq!(eg.edge_id(1, 2), Some(3));
        assert_eq!(eg.edge_id(2, 3), Some(4));
        assert_eq!(eg.edge_id(1, 3), None);
        // eo: vertex 0 has no smaller neighbors → eo[0] == xadj[0]
        assert_eq!(eg.eo[0], eg.g.xadj[0]);
        // vertex 3 has only smaller neighbors → eo[3] == xadj[4]
        assert_eq!(eg.eo[3], eg.g.xadj[4]);
    }

    #[test]
    fn deg_plus_sums_to_m() {
        let g = gen::rmat(256, 1024, 0.57, 0.19, 0.19, 42);
        let eg = EdgeGraph::new(g);
        let total: usize = (0..eg.n()).map(|u| eg.deg_plus(u as Vertex)).sum();
        assert_eq!(total, eg.m());
    }

    #[test]
    fn edge_graph_random_always_valid() {
        forall("edge-graph-valid", 24, |rng| {
            let n = rng.range(2, 48);
            let p = rng.f64() * 0.4;
            let g = gen::erdos_renyi(n, p, rng.next_u64());
            EdgeGraph::new(g).validate();
        });
    }
}
