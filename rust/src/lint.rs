//! `pallas lint` — a concurrency-hygiene source lint for this crate.
//!
//! Clippy cannot see project conventions, so this pass enforces the ones
//! the concurrency work relies on (CI runs it via `pallas lint rust/src`):
//!
//! 1. **`unsafe-safety`** — every `unsafe` keyword carries a `SAFETY:`
//!    comment, on the same line or in the contiguous comment/attribute
//!    block directly above.
//! 2. **`seqcst-ordering`** — `SeqCst` is banned unless an `ORDERING:`
//!    comment justifies why a weaker ordering does not suffice.
//! 3. **`server-unwrap`** — no `.unwrap()` / `.expect(` in the request
//!    path of `coordinator/server.rs`: a panic there kills a client
//!    connection thread silently instead of returning an `ERR` line.
//! 4. **`atomic-import`** — atomics come from `crate::par::sync::atomic`
//!    (the loom shim), never `std::sync::atomic` directly; code that
//!    bypasses the shim is invisible to the loom models.
//! 5. **`coordinator-spawn`** — thread creation (`thread::spawn` /
//!    `thread::Builder`) in `coordinator/` needs a `SPAWN:` comment
//!    stating who bounds and joins the thread: unaccounted spawns are
//!    how the server's unbounded-concurrency bug happened, and new work
//!    belongs on the executor pool, not ad-hoc threads.
//!
//! The scanner is text-level but syntax-aware where it matters: string
//! literals (including multi-line and raw `r#"…"#` strings), `//` and
//! nested `/* */` comments, and char-literal-vs-lifetime ambiguity are
//! resolved before any rule pattern runs, so a pattern inside a string
//! or comment never fires — which is also what lets this file lint
//! itself cleanly while naming every pattern it searches for. Test
//! modules are exempt from all rules: by crate convention they are a
//! tail `#[cfg(test)]` (or `#[cfg(all(test, …))]`) module, and
//! everything from that attribute down is skipped.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One rule hit: which rule, where, and why it matters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LintViolation {
    /// Rule slug, e.g. `unsafe-safety`.
    pub rule: &'static str,
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for LintViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Result of linting a tree of files.
#[derive(Debug, Default)]
pub struct LintOutcome {
    pub files_scanned: usize,
    pub violations: Vec<LintViolation>,
}

/// Lint every `.rs` file under `root` (a directory or a single file),
/// in sorted order for stable output.
pub fn lint_tree(root: &Path) -> Result<LintOutcome> {
    let mut files = vec![];
    collect_rs(root, &mut files)
        .with_context(|| format!("walking {}", root.display()))?;
    files.sort();
    let mut out = LintOutcome::default();
    for f in files {
        let src = std::fs::read_to_string(&f)
            .with_context(|| format!("reading {}", f.display()))?;
        out.files_scanned += 1;
        out.violations
            .extend(lint_source(&f.to_string_lossy(), &src));
    }
    Ok(out)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if path.is_file() {
        if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    for entry in std::fs::read_dir(path)? {
        collect_rs(&entry?.path(), out)?;
    }
    Ok(())
}

/// Scanner state carried across lines: strings and block comments span
/// line boundaries.
#[derive(Clone, Copy)]
enum Mode {
    Code,
    /// Inside `"…"`; `escape` set when the previous char was `\`.
    Str { escape: bool },
    /// Inside `r"…"` / `r#"…"#`; closes on `"` followed by `hashes` `#`s.
    RawStr { hashes: usize },
    /// Inside `/* … */`, which nests in Rust.
    Block { depth: usize },
}

/// Split one line into (code, comment) with string-literal *contents*
/// dropped from the code part (delimiters kept), returning the state the
/// next line starts in.
fn split_line(line: &str, mut mode: Mode) -> (String, String, Mode) {
    let chars: Vec<char> = line.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match mode {
            Mode::Str { escape } => {
                if escape {
                    mode = Mode::Str { escape: false };
                } else if c == '\\' {
                    mode = Mode::Str { escape: true };
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Code;
                }
                i += 1;
            }
            Mode::RawStr { hashes } => {
                if c == '"' && chars[i + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
                {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
            Mode::Block { depth } => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::Block { depth: depth - 1 } };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::Block { depth: depth + 1 };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    comment.extend(&chars[i + 2..]);
                    i = chars.len();
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::Block { depth: 1 };
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    mode = Mode::Str { escape: false };
                    i += 1;
                } else if c == 'r' && raw_string_hashes(&chars, i).is_some() {
                    let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
                    match raw_string_hashes(&chars, i) {
                        Some(h) if !prev_ident => {
                            code.push('"');
                            mode = Mode::RawStr { hashes: h };
                            i += 2 + h; // r, hashes, opening quote
                        }
                        _ => {
                            code.push(c);
                            i += 1;
                        }
                    }
                } else if c == '\'' {
                    // char literal vs lifetime: escaped chars end at the
                    // next quote; a one-char literal closes two ahead;
                    // anything else is a lifetime tick.
                    if chars.get(i + 1) == Some(&'\\') {
                        let close = chars[i + 2..].iter().position(|&x| x == '\'');
                        i = match close {
                            Some(k) => i + 2 + k + 1,
                            None => i + 1,
                        };
                    } else if chars.get(i + 2) == Some(&'\'') {
                        i += 3;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    (code, comment, mode)
}

/// `Some(hashes)` if `chars[at]` starts a raw string (`r"`, `r#"`, …).
fn raw_string_hashes(chars: &[char], at: usize) -> Option<usize> {
    let mut j = at + 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// Whole-word search (the needle must not be flanked by ident chars).
fn contains_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    let w = word.as_bytes();
    if w.is_empty() || b.len() < w.len() {
        return false;
    }
    let ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    b.windows(w.len()).enumerate().any(|(p, win)| {
        win == w
            && (p == 0 || !ident(b[p - 1]))
            && (p + w.len() == b.len() || !ident(b[p + w.len()]))
    })
}

/// Lint one file's source. `path` decides the path-scoped rules (the
/// server unwrap ban, the sync-shim exemption).
pub fn lint_source(path: &str, src: &str) -> Vec<LintViolation> {
    let norm = path.replace('\\', "/");
    let is_sync_shim = norm.ends_with("par/sync.rs");
    let is_server = norm.ends_with("coordinator/server.rs");
    let is_coordinator = norm.contains("/coordinator/") || norm.starts_with("coordinator/");

    // (raw trimmed line, code part, comment part) per line
    let mut mode = Mode::Code;
    let mut lines: Vec<(String, String, String)> = vec![];
    for line in src.lines() {
        let (code, comment, next) = split_line(line, mode);
        mode = next;
        lines.push((line.trim().to_string(), code, comment));
    }

    // everything from the tail test module's cfg attribute down is exempt
    let test_start = lines
        .iter()
        .position(|(raw, _, _)| {
            raw.starts_with("#[cfg(") && raw.contains("test") && !raw.contains("not(test")
        })
        .unwrap_or(lines.len());

    // a marker counts on the offending line itself or anywhere in the
    // contiguous comment/attribute block directly above it
    let has_marker = |at: usize, marker: &str| -> bool {
        if lines[at].2.contains(marker) {
            return true;
        }
        let mut j = at;
        while j > 0 {
            j -= 1;
            let raw = &lines[j].0;
            if raw.starts_with("//") || raw.starts_with("#[") {
                if lines[j].2.contains(marker) {
                    return true;
                }
            } else {
                break;
            }
        }
        false
    };

    let mut out = vec![];
    let mut fail = |rule: &'static str, line: usize, message: String| {
        out.push(LintViolation { rule, file: path.to_string(), line: line + 1, message });
    };
    for (idx, (_raw, code, _comment)) in lines.iter().enumerate().take(test_start) {
        if contains_word(code, "unsafe") && !has_marker(idx, "SAFETY:") {
            fail(
                "unsafe-safety",
                idx,
                "`unsafe` without a `SAFETY:` comment explaining why it is sound".into(),
            );
        }
        if contains_word(code, "SeqCst") && !has_marker(idx, "ORDERING:") {
            fail(
                "seqcst-ordering",
                idx,
                "`SeqCst` without an `ORDERING:` comment justifying the strongest ordering".into(),
            );
        }
        if is_server && (code.contains(".unwrap()") || code.contains(".expect(")) {
            fail(
                "server-unwrap",
                idx,
                "no panicking result-handling in the server request path; return ERR instead"
                    .into(),
            );
        }
        if !is_sync_shim && code.contains("std::sync::atomic") {
            fail(
                "atomic-import",
                idx,
                "use crate::par::sync::atomic (the loom shim) instead of std::sync::atomic"
                    .into(),
            );
        }
        if is_coordinator
            && (code.contains("thread::spawn") || code.contains("thread::Builder"))
            && !has_marker(idx, "SPAWN:")
        {
            fail(
                "coordinator-spawn",
                idx,
                "thread creation in coordinator/ needs a `SPAWN:` comment naming its \
                 bound and join point; job work belongs on the executor pool"
                    .into(),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(path: &str, src: &str) -> Vec<&'static str> {
        lint_source(path, src).iter().map(|v| v.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_flagged() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let v = lint_source("a.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "unsafe-safety");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn safety_marker_suppresses() {
        // same line
        assert!(rules("a.rs", "unsafe { x() } // SAFETY: x is fine\n").is_empty());
        // contiguous comment block above, including through an attribute
        let src = "// SAFETY: slot is exclusively reserved\n#[inline]\nunsafe fn g() {}\n";
        assert!(rules("a.rs", src).is_empty());
        // a blank line breaks contiguity
        let src = "// SAFETY: stale\n\nunsafe fn g() {}\n";
        assert_eq!(rules("a.rs", src), vec!["unsafe-safety"]);
    }

    #[test]
    fn seqcst_requires_ordering() {
        let src = "fn f(a: &A) { a.store(1, Ordering::SeqCst); }\n";
        assert_eq!(rules("a.rs", src), vec!["seqcst-ordering"]);
        let src = "// ORDERING: store-load fence needed between X and Y\nfn f(a: &A) { a.store(1, Ordering::SeqCst); }\n";
        assert!(rules("a.rs", src).is_empty());
    }

    #[test]
    fn server_unwrap_only_in_server_path() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\nfn g(r: R) { r.expect(\"boom\"); }\n";
        assert_eq!(
            rules("src/coordinator/server.rs", src),
            vec!["server-unwrap", "server-unwrap"]
        );
        assert!(rules("src/coordinator/pipeline.rs", src).is_empty());
    }

    #[test]
    fn atomic_import_allowed_only_in_shim() {
        let src = "use std::sync::atomic::AtomicUsize;\n";
        assert_eq!(rules("src/truss/pkt.rs", src), vec!["atomic-import"]);
        assert!(rules("src/par/sync.rs", src).is_empty());
    }

    #[test]
    fn coordinator_spawn_needs_marker() {
        let src = "fn f() { std::thread::spawn(|| work()); }\n";
        assert_eq!(rules("src/coordinator/server.rs", src), vec!["coordinator-spawn"]);
        let src = "fn f() { let b = std::thread::Builder::new(); }\n";
        assert_eq!(rules("src/coordinator/executor.rs", src), vec!["coordinator-spawn"]);
        // a SPAWN: comment above (or on the line) suppresses
        let src = "// SPAWN: one per connection, exits with the socket\n\
                   fn f() { std::thread::spawn(|| work()); }\n";
        assert!(rules("src/coordinator/server.rs", src).is_empty());
        let src = "fn f() { std::thread::spawn(|| w()); } // SPAWN: joined below\n";
        assert!(rules("src/coordinator/server.rs", src).is_empty());
        // outside coordinator/ the rule does not apply
        let src = "fn f() { std::thread::spawn(|| work()); }\n";
        assert!(rules("src/par/runtime.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let src = concat!(
            "fn f() -> &'static str {\n",
            "    // unsafe SeqCst std::sync::atomic in a comment is fine\n",
            "    \"unsafe SeqCst std::sync::atomic .unwrap()\"\n",
            "}\n"
        );
        assert!(rules("src/coordinator/server.rs", src).is_empty());
    }

    #[test]
    fn multi_line_and_raw_strings_skipped() {
        let src = "let a = \"line one\n  unsafe line two\";\nlet b = r#\"SeqCst \"quoted\" inside\"#;\n";
        assert!(rules("a.rs", src).is_empty());
        // raw string spanning lines
        let src = "let c = r#\"\n unsafe\n SeqCst\n\"#;\n";
        assert!(rules("a.rs", src).is_empty());
    }

    #[test]
    fn char_literals_and_lifetimes() {
        // a quote char literal must not open string mode and hide the
        // unsafe that follows
        let src = "fn f(c: char) -> bool { c == '\"' }\nfn g() { unsafe { h() } }\n";
        assert_eq!(rules("a.rs", src), vec!["unsafe-safety"]);
        // lifetimes don't start char-literal mode either
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nunsafe fn g() {}\n";
        assert_eq!(rules("a.rs", src), vec!["unsafe-safety"]);
    }

    #[test]
    fn word_boundaries_respected() {
        // identifiers containing the keyword are not the keyword
        let src = "fn f(unsafe_count: usize) -> usize { unsafe_count }\n";
        assert!(rules("a.rs", src).is_empty());
        assert!(contains_word("unsafe {", "unsafe"));
        assert!(!contains_word("my_unsafe_fn()", "unsafe"));
    }

    #[test]
    fn test_tail_is_exempt() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { unsafe { x() } }\n}\n";
        assert!(rules("a.rs", src).is_empty());
        let src = "#[cfg(all(test, not(loom)))]\nmod tests {\n    fn t() { unsafe { x() } }\n}\n";
        assert!(rules("a.rs", src).is_empty());
        // ...but unsafe *before* the test module is still caught
        let src = "fn prod() { unsafe { x() } }\n#[cfg(test)]\nmod tests {}\n";
        assert_eq!(rules("a.rs", src), vec!["unsafe-safety"]);
    }

    #[test]
    fn violation_display_format() {
        let v = LintViolation {
            rule: "unsafe-safety",
            file: "src/par/mod.rs".into(),
            line: 42,
            message: "msg".into(),
        };
        assert_eq!(v.to_string(), "src/par/mod.rs:42: [unsafe-safety] msg");
    }

    #[test]
    fn lint_tree_walks_files() {
        let dir = std::env::temp_dir().join(format!("trussx-lint-{}", std::process::id()));
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("ok.rs"), "fn f() {}\n").unwrap();
        std::fs::write(dir.join("sub/bad.rs"), "unsafe fn g() {}\n").unwrap();
        std::fs::write(dir.join("notrust.txt"), "unsafe\n").unwrap();
        let out = lint_tree(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        assert_eq!(out.files_scanned, 2);
        assert_eq!(out.violations.len(), 1);
        assert!(out.violations[0].file.ends_with("bad.rs"));
    }

    #[test]
    fn own_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let out = lint_tree(&root).unwrap();
        assert!(out.files_scanned > 10, "walked {} files", out.files_scanned);
        let msgs: Vec<String> = out.violations.iter().map(|v| v.to_string()).collect();
        assert!(msgs.is_empty(), "own sources must lint clean:\n{}", msgs.join("\n"));
        // the dynamic-maintenance module is explicitly in the covered
        // tree (guards against the walk silently skipping a file) and
        // lints clean on its own
        let dynamic = root.join("truss").join("dynamic.rs");
        assert!(dynamic.is_file(), "{} missing", dynamic.display());
        let single = lint_tree(&dynamic).unwrap();
        assert_eq!(single.files_scanned, 1);
        assert!(
            single.violations.is_empty(),
            "truss/dynamic.rs must lint clean:\n{}",
            single.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
