//! Small shared utilities: deterministic PRNGs, a tiny property-testing
//! harness, and formatting helpers.
//!
//! Nothing here pulls in external crates — the offline registry only
//! carries the `xla` closure, so randomness and property testing are
//! hand-rolled (SplitMix64 / xoshiro256**, both public-domain algorithms).

/// SplitMix64 — used to seed xoshiro and for cheap standalone streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main PRNG for generators and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Minimal property-testing harness: runs `cases` randomized cases with
/// deterministic per-case seeds; on failure the panic message carries the
/// failing seed so the case can be replayed.
///
/// ```
/// use trussx::util::forall;
/// forall("sum-commutes", 64, |rng| {
///     let a = rng.below(1000) as i64;
///     let b = rng.below(1000) as i64;
///     assert_eq!(a + b, b + a);
/// });
/// ```
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    // Deterministic per-property seed so CI runs are reproducible but
    // different properties explore different streams.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// FNV-1a — stable string hash used for property seeds.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Human-friendly duration formatting for tables (seconds with adaptive
/// precision, matching the paper's table style).
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 0.001 {
        format!("{s:.4}")
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

/// Pretty-print a large count with digit grouping (e.g. `12_345_678`).
pub fn fmt_count(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(*b as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_below_roughly_uniform() {
        let mut rng = Rng::new(123);
        let mut counts = [0u32; 8];
        let trials = 80_000;
        for _ in 0..trials {
            counts[rng.below(8) as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10k; allow ±10%
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 32, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property 'bad'")]
    fn forall_reports_failure() {
        forall("bad", 8, |rng| {
            assert!(rng.below(2) == 0, "coin came up 1");
        });
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn fmt_count_groups() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1_000");
        assert_eq!(fmt_count(1234567), "1_234_567");
    }
}
