//! trussx — shared-memory graph truss decomposition (PKT).
//!
//! Reproduction of Kabir & Madduri, "Shared-memory Graph Truss
//! Decomposition" (2017). Three-layer architecture:
//!
//! - **L3 (this crate)**: the paper's contribution — the PKT
//!   level-synchronous parallel truss decomposition, plus every substrate
//!   it depends on (CSR graph store, generators, k-core decomposition,
//!   ordering, oriented triangle counting, baselines WC/Ros, a parallel
//!   runtime with thread-local buffers and barriers, metrics, CLI).
//! - **L2 (python/compile/model.py)**: dense linear-algebra truss support
//!   model (Graphulo-style `S = (A·A) ⊙ A`) lowered AOT to HLO text.
//! - **L1 (python/compile/kernels/)**: Pallas tiled masked-matmul kernel
//!   called from L2; checked against a pure-jnp oracle.
//!
//! The Rust binary can load the AOT artifacts via the `xla` crate (PJRT
//! CPU client) — Python is never on the request path. That path is
//! gated behind the off-by-default `xla` cargo feature so the default
//! build stays dependency-free and offline.
//!
//! Cross-cutting: the [`obs`] subsystem (std-only metrics registry,
//! RAII phase spans, JSONL trace sink, Prometheus exposition) is wired
//! through the runtime, the kernels, and the coordinator.

pub mod bench;
pub mod coordinator;
pub mod gen;
pub mod graph;
pub mod kcore;
pub mod metrics;
pub mod obs;
pub mod order;
pub mod par;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod triangle;
pub mod truss;
pub mod util;
