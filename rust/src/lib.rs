//! trussx — shared-memory graph truss decomposition (PKT).
//!
//! Reproduction of Kabir & Madduri, "Shared-memory Graph Truss
//! Decomposition" (2017). Three-layer architecture:
//!
//! - **L3 (this crate)**: the paper's contribution — the PKT
//!   level-synchronous parallel truss decomposition, plus every substrate
//!   it depends on (CSR graph store, generators, k-core decomposition,
//!   ordering, oriented triangle counting, baselines WC/Ros, a parallel
//!   runtime with thread-local buffers and barriers, metrics, CLI).
//! - **L2 (python/compile/model.py)**: dense linear-algebra truss support
//!   model (Graphulo-style `S = (A·A) ⊙ A`) lowered AOT to HLO text.
//! - **L1 (python/compile/kernels/)**: Pallas tiled masked-matmul kernel
//!   called from L2; checked against a pure-jnp oracle.
//!
//! The Rust binary can load the AOT artifacts via the `xla` crate (PJRT
//! CPU client) — Python is never on the request path. That path is
//! gated behind the off-by-default `xla` cargo feature so the default
//! build stays dependency-free and offline.
//!
//! Cross-cutting: the [`obs`] subsystem (std-only metrics registry,
//! RAII phase spans, JSONL trace sink, Prometheus exposition) is wired
//! through the runtime, the kernels, and the coordinator.

// Under `RUSTFLAGS="--cfg loom"` (see `par::sync`) only the concurrency
// core and its model tests build: the rest of the crate leans on std
// facilities loom cannot schedule (OnceLock statics, scoped threads,
// barriers, TCP, timers), so it is gated out of the model build.
#[cfg(not(loom))]
pub mod bench;
#[cfg(not(loom))]
pub mod coordinator;
#[cfg(not(loom))]
pub mod gen;
#[cfg(not(loom))]
pub mod graph;
#[cfg(not(loom))]
pub mod kcore;
#[cfg(not(loom))]
pub mod lint;
#[cfg(not(loom))]
pub mod metrics;
#[cfg(not(loom))]
pub mod obs;
#[cfg(not(loom))]
pub mod order;
pub mod par;
#[cfg(all(feature = "xla", not(loom)))]
pub mod runtime;
#[cfg(not(loom))]
pub mod triangle;
#[cfg(not(loom))]
pub mod truss;
#[cfg(not(loom))]
pub mod util;
#[cfg(not(loom))]
pub mod validate;
