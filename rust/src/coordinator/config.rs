//! Job configuration: graph source, preprocessing, algorithm, threads.
//!
//! Everything is parseable from compact spec strings so the CLI, the
//! server protocol, and the examples share one format:
//!
//! ```text
//! graph spec:  suite:web-pp-s | rmat:n=1024,m=8192 | er:n=500,p=0.05
//!              | ba:n=1000,k=4 | ws:n=500,k=4,beta=0.1
//!              | pp:blocks=8,size=24,pin=0.7,pout=0.001
//!              | complete:n=16 | file:/path/to/graph.el
//! algorithm:   pkt | wc | ros | local
//! ```

use crate::graph::{io, Graph};
use crate::order::Ordering;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Which decomposition algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Pkt,
    Wc,
    Ros,
    Local,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pkt" => Ok(Self::Pkt),
            "wc" => Ok(Self::Wc),
            "ros" => Ok(Self::Ros),
            "local" => Ok(Self::Local),
            _ => bail!("unknown algorithm '{s}' (pkt|wc|ros|local)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Pkt => "pkt",
            Self::Wc => "wc",
            Self::Ros => "ros",
            Self::Local => "local",
        }
    }
}

/// A graph source description.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphSpec {
    Suite { name: String, scale: usize },
    Rmat { n: usize, m: usize, a: f64, b: f64, c: f64, seed: u64 },
    Er { n: usize, p: f64, seed: u64 },
    Ba { n: usize, k: usize, seed: u64 },
    Ws { n: usize, k: usize, beta: f64, seed: u64 },
    Planted { blocks: usize, size: usize, p_in: f64, p_out: f64, seed: u64 },
    Complete { n: usize },
    File { path: String },
}

fn params(body: &str) -> Result<HashMap<&str, &str>> {
    let mut out = HashMap::new();
    for kv in body.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("bad param '{kv}' (want key=value)"))?;
        out.insert(k.trim(), v.trim());
    }
    Ok(out)
}

fn get<T: std::str::FromStr>(p: &HashMap<&str, &str>, key: &str, default: T) -> Result<T> {
    match p.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("bad value for '{key}': {v}")),
    }
}

impl GraphSpec {
    /// Parse a `kind:params` spec string.
    pub fn parse(s: &str) -> Result<Self> {
        let (kind, body) = s.split_once(':').unwrap_or((s, ""));
        match kind {
            "suite" => Ok(Self::Suite {
                name: body.split(',').next().unwrap_or("").to_string(),
                scale: 1,
            }),
            "rmat" => {
                let p = params(body)?;
                Ok(Self::Rmat {
                    n: get(&p, "n", 1024)?,
                    m: get(&p, "m", 4096)?,
                    a: get(&p, "a", 0.57)?,
                    b: get(&p, "b", 0.19)?,
                    c: get(&p, "c", 0.19)?,
                    seed: get(&p, "seed", 42)?,
                })
            }
            "er" => {
                let p = params(body)?;
                Ok(Self::Er {
                    n: get(&p, "n", 1000)?,
                    p: get(&p, "p", 0.01)?,
                    seed: get(&p, "seed", 42)?,
                })
            }
            "ba" => {
                let p = params(body)?;
                Ok(Self::Ba {
                    n: get(&p, "n", 1000)?,
                    k: get(&p, "k", 4)?,
                    seed: get(&p, "seed", 42)?,
                })
            }
            "ws" => {
                let p = params(body)?;
                Ok(Self::Ws {
                    n: get(&p, "n", 1000)?,
                    k: get(&p, "k", 4)?,
                    beta: get(&p, "beta", 0.1)?,
                    seed: get(&p, "seed", 42)?,
                })
            }
            "pp" => {
                let p = params(body)?;
                Ok(Self::Planted {
                    blocks: get(&p, "blocks", 8)?,
                    size: get(&p, "size", 24)?,
                    p_in: get(&p, "pin", 0.7)?,
                    p_out: get(&p, "pout", 0.001)?,
                    seed: get(&p, "seed", 42)?,
                })
            }
            "complete" => {
                let p = params(body)?;
                Ok(Self::Complete { n: get(&p, "n", 8)? })
            }
            "file" => Ok(Self::File { path: body.to_string() }),
            _ => bail!("unknown graph spec kind '{kind}'"),
        }
    }

    /// Materialize the graph.
    pub fn build(&self) -> Result<Graph> {
        Ok(match self {
            Self::Suite { name, scale } => {
                crate::gen::suite_by_name(name, *scale)
                    .with_context(|| format!("unknown suite graph '{name}'"))?
                    .graph
            }
            Self::Rmat { n, m, a, b, c, seed } => crate::gen::rmat(*n, *m, *a, *b, *c, *seed),
            Self::Er { n, p, seed } => crate::gen::erdos_renyi(*n, *p, *seed),
            Self::Ba { n, k, seed } => crate::gen::barabasi_albert(*n, *k, *seed),
            Self::Ws { n, k, beta, seed } => crate::gen::watts_strogatz(*n, *k, *beta, *seed),
            Self::Planted { blocks, size, p_in, p_out, seed } => {
                crate::gen::planted_partition(*blocks, *size, *p_in, *p_out, *seed)
            }
            Self::Complete { n } => crate::gen::complete(*n),
            Self::File { path } => io::read_auto(path)?,
        })
    }

    /// Short description for reports.
    pub fn describe(&self) -> String {
        match self {
            Self::Suite { name, .. } => format!("suite:{name}"),
            Self::Rmat { n, m, .. } => format!("rmat(n={n},m={m})"),
            Self::Er { n, p, .. } => format!("er(n={n},p={p})"),
            Self::Ba { n, k, .. } => format!("ba(n={n},k={k})"),
            Self::Ws { n, k, beta, .. } => format!("ws(n={n},k={k},beta={beta})"),
            Self::Planted { blocks, size, .. } => format!("pp({blocks}x{size})"),
            Self::Complete { n } => format!("K{n}"),
            Self::File { path } => format!("file:{path}"),
        }
    }
}

/// A full decomposition job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub graph: GraphSpec,
    pub ordering: Ordering,
    pub algorithm: Algorithm,
    pub threads: usize,
    /// PKT peel tuning (compaction threshold, packed flags); ignored by
    /// the other algorithms.
    pub pkt: crate::truss::PktConfig,
    /// Run deep structural validation around the decomposition (see
    /// [`crate::validate`]); also enabled process-wide by
    /// `TRUSSX_VALIDATE=1`.
    pub validate: bool,
    /// Per-job deadline in seconds (`timeout=` protocol option,
    /// `--job-timeout` on the CLI). `None` = no deadline. The executor
    /// arms a [`crate::par::CancelToken`] with it; the job stops at the
    /// next level/chunk boundary once it expires.
    pub timeout: Option<f64>,
}

impl JobConfig {
    pub fn new(graph: GraphSpec) -> Self {
        Self {
            graph,
            ordering: Ordering::KCore,
            algorithm: Algorithm::Pkt,
            threads: crate::par::Pool::default_threads(),
            pkt: crate::truss::PktConfig::default(),
            validate: false,
            timeout: None,
        }
    }

    pub fn algorithm(mut self, a: Algorithm) -> Self {
        self.algorithm = a;
        self
    }

    pub fn ordering(mut self, o: Ordering) -> Self {
        self.ordering = o;
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }

    pub fn pkt(mut self, p: crate::truss::PktConfig) -> Self {
        self.pkt = p;
        self
    }

    pub fn validate(mut self, v: bool) -> Self {
        self.validate = v;
        self
    }

    pub fn timeout(mut self, secs: f64) -> Self {
        self.timeout = Some(secs);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(
            GraphSpec::parse("complete:n=5").unwrap(),
            GraphSpec::Complete { n: 5 }
        );
        assert_eq!(
            GraphSpec::parse("er:n=10,p=0.5,seed=7").unwrap(),
            GraphSpec::Er { n: 10, p: 0.5, seed: 7 }
        );
        match GraphSpec::parse("rmat:n=64,m=128").unwrap() {
            GraphSpec::Rmat { n: 64, m: 128, .. } => {}
            other => panic!("{other:?}"),
        }
        assert!(GraphSpec::parse("wat:x=1").is_err());
        assert!(GraphSpec::parse("er:n=x").is_err());
        assert!(GraphSpec::parse("er:nop").is_err());
    }

    #[test]
    fn specs_build() {
        let g = GraphSpec::parse("complete:n=6").unwrap().build().unwrap();
        assert_eq!(g.m(), 15);
        let g = GraphSpec::parse("pp:blocks=2,size=8,pin=1.0,pout=0.0")
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(g.m(), 2 * 28);
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("pkt").unwrap(), Algorithm::Pkt);
        assert_eq!(Algorithm::parse("local").unwrap(), Algorithm::Local);
        assert!(Algorithm::parse("magic").is_err());
    }

    #[test]
    fn job_builder() {
        let j = JobConfig::new(GraphSpec::Complete { n: 4 })
            .algorithm(Algorithm::Wc)
            .threads(2);
        assert_eq!(j.algorithm, Algorithm::Wc);
        assert_eq!(j.threads, 2);
        assert!(!j.validate, "validation is opt-in");
        assert!(j.validate(true).validate);
    }

    #[test]
    fn job_timeout_defaults_off() {
        let j = JobConfig::new(GraphSpec::Complete { n: 4 });
        assert!(j.timeout.is_none(), "deadlines are opt-in");
        assert_eq!(j.timeout(0.25).timeout, Some(0.25));
    }
}
