//! A multi-client truss-analytics server over TCP (std::net,
//! thread-per-connection readers — tokio is not available offline).
//!
//! Connections are cheap reader threads; the actual decompositions run
//! on the bounded [`Executor`] pool, so client count no longer equals
//! concurrent peel count. A full queue is refused up front with a
//! structured `ERR BUSY retry_after_ms=N` instead of stacking work.
//!
//! Line protocol (one request per line, one `OK ...` / `ERR ...` reply;
//! `METRICS` is the one multi-line reply, framed by its header):
//!
//! ```text
//! DECOMP <graphspec> [algo=pkt|wc|ros|local] [threads=N] [order=nat|deg|kco]
//!                    [compact=0.3] [bitsets=true]     (pkt peel tuning)
//!                    [validate=true]    (deep invariant checks, see crate::validate)
//!                    [timeout=SECS]     (per-job deadline → ERR DEADLINE)
//! HIST    <graphspec> [...same options]   → trussness histogram
//! LOAD    <name> <graphspec> [threads=N] [compact=..] [bitsets=..]
//!                    [timeout=SECS]       → decompose and keep the graph
//!                                           resident for dynamic updates
//! INSERT  <name> <u-v[,u-v...]> [validate=true] [timeout=SECS]
//!                                         → batch edge insertion
//! REMOVE  <name> <u-v[,u-v...]> [validate=true] [timeout=SECS]
//!                                         → batch edge deletion
//! UNLOAD  <name>                          → drop a resident graph
//! STATUS                                  → jobs, in-flight, queue, conns, uptime
//! METRICS                                 → OK lines=<N> + N exposition lines
//! QUIT                                    → close this connection
//! ```
//!
//! LOAD / INSERT / REMOVE run on the same bounded executor as DECOMP,
//! so admission control, per-job deadlines, cancellation and drain all
//! apply. A resident graph keeps its **natural vertex ids** (LOAD never
//! reorders), so the edge lists in update requests refer to the ids of
//! the loaded graph; inserts may name vertices past the current maximum,
//! which grows the vertex set. INSERT/REMOVE replies are the
//! [`crate::truss::UpdateReport`] summary (`OK op=insert requested=..
//! applied=.. skipped=.. affected=.. ... tmax=..`); dirty batch entries
//! (self-loops, duplicates, already-present / already-absent edges) are
//! skipped and counted, never errors. Updates on one graph serialize on
//! that graph's lock; the lock wait itself polls the job's token, so a
//! `timeout=` covers queueing on a busy graph too.
//!
//! Error replies a client must be ready to handle:
//!
//! ```text
//! ERR BUSY retry_after_ms=<N>   queue full — back off and retry
//! ERR DEADLINE <detail>         the job's timeout= expired mid-run
//! ERR CANCELLED <detail>        cancelled (e.g. server drain deadline)
//! ERR SHUTDOWN draining         server is shutting down
//! ERR line too long (...)       request exceeded 64 KiB; line dropped
//! ERR <message>                 parse/validation/internal errors
//! ```
//!
//! Every request is counted, timed, and error-tracked per verb in the
//! global `obs` registry (`server_requests_total{verb=..}`,
//! `server_errors_total{verb=..}`, `server_request_seconds{verb=..}`);
//! the executor adds `server_rejected_total`, `server_timeouts_total`,
//! `server_cancelled_total`, `server_inflight_jobs` and
//! `server_queue_depth`. `METRICS` serves it all back in Prometheus
//! text format. Structured refusals (BUSY/DEADLINE/CANCELLED) are
//! tracked by their own counters, not `server_errors_total` — they are
//! protocol outcomes the client is expected to act on, not faults.

use super::executor::{Executor, ExecutorConfig, JobOutcome, JobTicket, LoadReport, SubmitError};
use super::{Algorithm, GraphSpec, JobConfig};
use crate::graph::Vertex;
use crate::obs;
use crate::order::Ordering as VOrdering;
use crate::par::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::par::{CancelToken, Cancelled};
use crate::truss::DynamicTruss;
use anyhow::{anyhow, ensure, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Longest accepted request line. A client streaming an unterminated
/// line used to grow the read buffer without bound; past this cap the
/// line is dropped and refused, and the connection stays usable.
const MAX_LINE_BYTES: u64 = 64 * 1024;

/// Server tuning: executor sizing plus the shutdown drain budget.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub executor: ExecutorConfig,
    /// How long [`ServerHandle::shutdown`] waits for in-flight and
    /// queued jobs before cancelling them through their tokens.
    pub drain: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { executor: ExecutorConfig::default(), drain: Duration::from_secs(5) }
    }
}

struct ServerState {
    stop: AtomicBool,
    jobs: AtomicU64,
    /// Live client connections (reader threads).
    conns: AtomicU64,
    started: Instant,
    executor: Executor,
    /// Resident graphs for the dynamic verbs, by client-chosen name.
    /// Double-wrapped: the outer lock guards the registry map, the
    /// per-graph `Arc<Mutex<..>>` lets an update job hold its graph
    /// after dispatch returns (and serializes updates per graph).
    graphs: Arc<Mutex<HashMap<String, Arc<Mutex<DynamicTruss>>>>>,
    workers: usize,
    queue_depth: usize,
}

/// Handle to a running server; dropping it does NOT stop the server —
/// call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    state: Arc<ServerState>,
    join: Option<std::thread::JoinHandle<()>>,
    drain: Duration,
}

impl ServerHandle {
    /// Jobs served so far.
    pub fn jobs_served(&self) -> u64 {
        self.state.jobs.load(Ordering::Relaxed)
    }

    /// Live client connections right now.
    pub fn connections(&self) -> u64 {
        self.state.conns.load(Ordering::Relaxed)
    }

    /// Graceful drain: stop accepting, join the accept loop, then let
    /// the executor finish in-flight and queued jobs up to the drain
    /// deadline — stragglers are cancelled through their tokens, so
    /// this returns in bounded time even with a wedged job.
    pub fn shutdown(mut self) {
        // ORDERING: Release pairs with the Acquire load in the accept
        // loop; the flag is the only state published through this edge,
        // so SeqCst's total order buys nothing (loom-checked pattern:
        // par::loom_model::loom_level_boundary_publish).
        self.state.stop.store(true, Ordering::Release);
        // poke the accept loop awake
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.state.executor.shutdown(self.drain);
    }
}

/// Start the server on `addr` (use port 0 for an ephemeral port) with
/// default tuning. Returns once the listener is bound.
pub fn serve(addr: &str) -> Result<ServerHandle> {
    serve_with(addr, ServerConfig::default())
}

/// [`serve`] with explicit executor sizing and drain budget.
pub fn serve_with(addr: &str, cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let state = Arc::new(ServerState {
        stop: AtomicBool::new(false),
        jobs: AtomicU64::new(0),
        conns: AtomicU64::new(0),
        started: Instant::now(),
        executor: Executor::new(&cfg.executor),
        graphs: Arc::new(Mutex::new(HashMap::new())),
        workers: cfg.executor.workers.max(1),
        queue_depth: cfg.executor.queue_depth.max(1),
    });
    let accept_state = state.clone();
    // SPAWN: the accept loop; joined in ServerHandle::shutdown after
    // the stop flag is raised and the listener poked awake.
    let join = std::thread::spawn(move || {
        for conn in listener.incoming() {
            // ORDERING: Acquire pairs with the Release store in
            // `ServerHandle::shutdown`.
            if accept_state.stop.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let st = accept_state.clone();
            // SPAWN: one cheap reader thread per connection — it blocks
            // on the socket; decompositions run on the bounded executor
            // pool, so this thread count does not bound CPU work.
            std::thread::spawn(move || {
                st.conns.fetch_add(1, Ordering::Relaxed);
                let _ = handle_connection(stream, &st);
                st.conns.fetch_sub(1, Ordering::Relaxed);
            });
        }
    });
    Ok(ServerHandle { addr: local, state, join: Some(join), drain: cfg.drain })
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        // cap the read: an unterminated line stops growing at the cap
        // instead of exhausting memory
        let n = (&mut reader).take(MAX_LINE_BYTES).read_line(&mut line)?;
        if n == 0 {
            return Ok(()); // client closed
        }
        if n as u64 == MAX_LINE_BYTES && !line.ends_with('\n') {
            // truncated an oversized line: discard the remainder so the
            // connection stays usable, refuse, keep serving
            let m = verb_metrics("UNKNOWN");
            m.requests.inc();
            m.errors.inc();
            skip_to_newline(&mut reader)?;
            writer.write_all(
                format!("ERR line too long (max {MAX_LINE_BYTES} bytes)\n").as_bytes(),
            )?;
            writer.flush()?;
            continue;
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        let verb = canonical_verb(req);
        let m = verb_metrics(verb);
        m.requests.inc();
        let t0 = Instant::now();
        let outcome = dispatch(req, state);
        m.latency.observe(t0.elapsed().as_secs_f64());
        let reply = match outcome {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // QUIT
            Err(e) => {
                m.errors.inc();
                format!("ERR {e:#}").replace('\n', " ")
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

/// Discard buffered input through the next newline (or EOF), after the
/// line cap truncated a request mid-line.
fn skip_to_newline(reader: &mut BufReader<TcpStream>) -> Result<()> {
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(()); // EOF — the final read_line will report it
        }
        match buf.iter().position(|&b| b == b'\n') {
            Some(i) => {
                reader.consume(i + 1);
                return Ok(());
            }
            None => {
                let len = buf.len();
                reader.consume(len);
            }
        }
    }
}

/// Normalize a request line to a static verb for metric labels (bounded
/// cardinality: arbitrary client input must never become a label value).
fn canonical_verb(req: &str) -> &'static str {
    let verb = req.split_whitespace().next().unwrap_or("").to_ascii_uppercase();
    match verb.as_str() {
        "DECOMP" => "DECOMP",
        "HIST" => "HIST",
        "LOAD" => "LOAD",
        "INSERT" => "INSERT",
        "REMOVE" => "REMOVE",
        "UNLOAD" => "UNLOAD",
        "STATUS" => "STATUS",
        "METRICS" => "METRICS",
        "QUIT" => "QUIT",
        _ => "UNKNOWN",
    }
}

struct VerbMetrics {
    requests: obs::Counter,
    errors: obs::Counter,
    latency: obs::Histogram,
}

fn verb_metrics(verb: &'static str) -> VerbMetrics {
    let r = obs::global();
    VerbMetrics {
        requests: r.counter("server_requests_total", &[("verb", verb)]),
        errors: r.counter("server_errors_total", &[("verb", verb)]),
        latency: r.histogram("server_request_seconds", &[("verb", verb)]),
    }
}

fn dispatch(req: &str, state: &ServerState) -> Result<Option<String>> {
    let mut parts = req.split_whitespace();
    let verb = parts.next().unwrap_or("").to_ascii_uppercase();
    match verb.as_str() {
        "QUIT" => Ok(None),
        "STATUS" => Ok(Some(format!(
            "OK jobs={} inflight={} queued={} conns={} graphs={} uptime_secs={:.3} \
             threads_default={} workers={} queue_depth={}",
            state.jobs.load(Ordering::Relaxed),
            state.executor.inflight(),
            state.executor.queued(),
            state.conns.load(Ordering::Relaxed),
            state.graphs.lock().map(|g| g.len()).unwrap_or(0),
            state.started.elapsed().as_secs_f64(),
            crate::par::Pool::default_threads(),
            state.workers,
            state.queue_depth,
        ))),
        "METRICS" => {
            let body = obs::expo::render(obs::global());
            let mut reply = format!("OK lines={}", body.lines().count());
            for l in body.lines() {
                reply.push('\n');
                reply.push_str(l);
            }
            Ok(Some(reply))
        }
        "DECOMP" | "HIST" => {
            let spec_str = parts.next().context("missing graph spec")?;
            let cfg = parse_job(spec_str, parts)?;
            let report = match wait_mapped(state, state.executor.submit(cfg))? {
                Err(refusal) => return Ok(Some(refusal)),
                Ok(outcome) => outcome.decomp()?,
            };
            if verb == "DECOMP" {
                Ok(Some(format!("OK {}", report.summary())))
            } else {
                let hist: Vec<String> = report
                    .histogram
                    .iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(k, &c)| format!("{k}:{c}"))
                    .collect();
                Ok(Some(format!("OK {}", hist.join(","))))
            }
        }
        "LOAD" => {
            let name = parts.next().context("missing graph name")?;
            validate_graph_name(name)?;
            let spec_str = parts.next().context("missing graph spec")?;
            let cfg = parse_job(spec_str, parts)?;
            let timeout = cfg.timeout;
            let registry = state.graphs.clone();
            let name = name.to_string();
            let job_name = name.clone();
            let sub = state.executor.submit_fn(
                timeout,
                Box::new(move |token: &CancelToken| {
                    // natural vertex order on purpose: update edge lists
                    // must keep referring to the input's vertex ids
                    let g = cfg.graph.build()?;
                    let dt = DynamicTruss::with_config_token(g, cfg.threads, cfg.pkt, token)?;
                    let rep = LoadReport {
                        name: job_name.clone(),
                        n: dt.n(),
                        m: dt.m(),
                        t_max: dt.t_max(),
                    };
                    let mut map = registry
                        .lock()
                        .map_err(|_| anyhow!("graph registry poisoned by an earlier panic"))?;
                    map.insert(job_name, Arc::new(Mutex::new(dt)));
                    Ok(JobOutcome::Load(rep))
                }),
            );
            let rep = match wait_mapped(state, sub)? {
                Err(refusal) => return Ok(Some(refusal)),
                Ok(outcome) => outcome.load()?,
            };
            Ok(Some(format!(
                "OK name={} n={} m={} tmax={}",
                rep.name, rep.n, rep.m, rep.t_max
            )))
        }
        "INSERT" | "REMOVE" => {
            let name = parts.next().context("missing graph name")?;
            let edges_str = parts.next().context("missing edge list")?;
            let edges = parse_edges(edges_str)?;
            let (timeout, validate) = parse_update_opts(parts)?;
            let handle = state
                .graphs
                .lock()
                .map_err(|_| anyhow!("graph registry poisoned by an earlier panic"))?
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow!("unknown graph '{name}' (LOAD it first)"))?;
            let insert = verb == "INSERT";
            let sub = state.executor.submit_fn(
                timeout,
                Box::new(move |token: &CancelToken| {
                    let mut dt = lock_graph(&handle, token)?;
                    let _guard = validate.then(crate::validate::enable_scoped);
                    let rep = if insert {
                        dt.insert_batch_with(&edges, token)?
                    } else {
                        dt.remove_batch_with(&edges, token)?
                    };
                    Ok(JobOutcome::Update(rep))
                }),
            );
            let rep = match wait_mapped(state, sub)? {
                Err(refusal) => return Ok(Some(refusal)),
                Ok(outcome) => outcome.update()?,
            };
            Ok(Some(format!("OK {}", rep.summary())))
        }
        "UNLOAD" => {
            let name = parts.next().context("missing graph name")?;
            let removed = state
                .graphs
                .lock()
                .map_err(|_| anyhow!("graph registry poisoned by an earlier panic"))?
                .remove(name);
            match removed {
                Some(_) => Ok(Some(format!("OK unloaded={name}"))),
                None => Err(anyhow!("unknown graph '{name}'")),
            }
        }
        _ => Err(anyhow!(
            "unknown verb '{verb}' (DECOMP|HIST|LOAD|INSERT|REMOVE|UNLOAD|STATUS|METRICS|QUIT)"
        )),
    }
}

/// Wait on a submitted job, mapping admission refusals and
/// cancellations to their structured protocol reply lines.
/// `Ok(Err(line))` is a refusal the client acts on; `Ok(Ok(..))` is a
/// finished job (counted in `jobs`); `Err` is a real fault.
fn wait_mapped(
    state: &ServerState,
    sub: std::result::Result<JobTicket, SubmitError>,
) -> Result<std::result::Result<JobOutcome, String>> {
    let ticket = match sub {
        Ok(t) => t,
        // admission refusals are structured protocol replies the client
        // acts on, not error-counter events
        Err(e) => return Ok(Err(format!("ERR {e}"))),
    };
    match ticket.wait() {
        Ok(outcome) => {
            state.jobs.fetch_add(1, Ordering::Relaxed);
            Ok(Ok(outcome))
        }
        Err(e) => {
            if let Some(c) = e.downcast_ref::<Cancelled>() {
                return Ok(Err(format!("ERR {} {}", c.reason.name(), c.describe())));
            }
            Err(e)
        }
    }
}

/// Acquire a resident graph's lock from inside an update job, polling
/// the job token while the graph is busy — a `timeout=` deadline (or an
/// explicit cancel) therefore also covers waiting on a contended graph.
fn lock_graph<'a>(
    handle: &'a Mutex<DynamicTruss>,
    token: &CancelToken,
) -> Result<std::sync::MutexGuard<'a, DynamicTruss>> {
    loop {
        match handle.try_lock() {
            Ok(g) => return Ok(g),
            Err(std::sync::TryLockError::WouldBlock) => {
                if token.should_stop().is_some() {
                    return Err(token
                        .stopped("dynamic.lock", "waiting for graph lock".into())
                        .into());
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(std::sync::TryLockError::Poisoned(_)) => {
                return Err(anyhow!("graph state poisoned by an earlier panic"));
            }
        }
    }
}

/// Graph names become registry keys and reply text — keep them short
/// and boring so arbitrary client bytes never round-trip into replies.
fn validate_graph_name(name: &str) -> Result<()> {
    ensure!(
        !name.is_empty()
            && name.len() <= 64
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
        "bad graph name (want 1-64 chars of [A-Za-z0-9_-])"
    );
    Ok(())
}

/// Parse the wire edge-list format `u-v[,u-v...]`, e.g. `0-1,4-2`.
/// Semantic dirt (self-loops, duplicates, present/absent edges) is NOT
/// rejected here — the batch ops skip and count it in their report.
fn parse_edges(s: &str) -> Result<Vec<(Vertex, Vertex)>> {
    let mut out = Vec::new();
    for pair in s.split(',').filter(|p| !p.is_empty()) {
        let (u, v) = pair
            .split_once('-')
            .with_context(|| format!("bad edge '{pair}' (want u-v)"))?;
        let u: Vertex = u.parse().with_context(|| format!("bad vertex '{u}' in '{pair}'"))?;
        let v: Vertex = v.parse().with_context(|| format!("bad vertex '{v}' in '{pair}'"))?;
        out.push((u, v));
    }
    ensure!(!out.is_empty(), "empty edge list (want u-v[,u-v...])");
    Ok(out)
}

/// Options accepted by INSERT / REMOVE (a strict subset of DECOMP's).
fn parse_update_opts<'a>(opts: impl Iterator<Item = &'a str>) -> Result<(Option<f64>, bool)> {
    let mut timeout = None;
    let mut validate = false;
    for opt in opts {
        let (k, v) = opt.split_once('=').with_context(|| format!("bad option '{opt}'"))?;
        match k {
            "timeout" => {
                let t: f64 = v.parse().context("bad timeout")?;
                ensure!(t.is_finite() && t >= 0.0, "bad timeout '{v}' (want seconds >= 0)");
                timeout = Some(t);
            }
            "validate" => validate = v.parse().context("bad validate flag")?,
            _ => return Err(anyhow!("unknown option '{k}' (timeout|validate)")),
        }
    }
    Ok((timeout, validate))
}

fn parse_job<'a>(spec_str: &str, opts: impl Iterator<Item = &'a str>) -> Result<JobConfig> {
    let spec = GraphSpec::parse(spec_str)?;
    let mut cfg = JobConfig::new(spec);
    for opt in opts {
        let (k, v) = opt.split_once('=').with_context(|| format!("bad option '{opt}'"))?;
        match k {
            "algo" => cfg.algorithm = Algorithm::parse(v)?,
            "threads" => cfg.threads = v.parse().context("bad threads")?,
            "order" => {
                cfg.ordering =
                    VOrdering::parse(v).with_context(|| format!("bad order '{v}'"))?
            }
            "compact" => {
                cfg.pkt.compact_threshold = v.parse().context("bad compact threshold")?
            }
            "bitsets" => cfg.pkt.use_bitsets = v.parse().context("bad bitsets flag")?,
            "validate" => cfg.validate = v.parse().context("bad validate flag")?,
            "timeout" => {
                let t: f64 = v.parse().context("bad timeout")?;
                // Duration::from_secs_f64 panics on negative/NaN input —
                // reject here so bad client input stays an ERR reply
                ensure!(t.is_finite() && t >= 0.0, "bad timeout '{v}' (want seconds >= 0)");
                cfg.timeout = Some(t);
            }
            _ => return Err(anyhow!("unknown option '{k}'")),
        }
    }
    Ok(cfg)
}

/// Small blocking client for the line protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Backoff jitter state for [`Client::request_with_retry`].
    seed: u64,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let seed = 0x9E37_79B9_7F4A_7C15 ^ u64::from(stream.local_addr()?.port());
        Ok(Self { reader: BufReader::new(stream.try_clone()?), writer: stream, seed })
    }

    /// Send one request line, read one reply line.
    pub fn request(&mut self, req: &str) -> Result<String> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim_end().to_string())
    }

    /// [`Client::request`] plus admission-control handling: on an
    /// `ERR BUSY` reply, sleep max(server hint, doubling backoff) plus
    /// jitter and retry, up to `max_retries` times. Returns the last
    /// reply either way — callers still check for `OK`.
    pub fn request_with_retry(&mut self, req: &str, max_retries: usize) -> Result<String> {
        let mut backoff_ms: u64 = 10;
        let mut reply = self.request(req)?;
        for _ in 0..max_retries {
            let Some(rest) = reply.strip_prefix("ERR BUSY") else {
                return Ok(reply);
            };
            let hint = rest
                .split_whitespace()
                .find_map(|f| f.strip_prefix("retry_after_ms="))
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(backoff_ms);
            // deterministic LCG jitter (no RNG dependency): desyncs
            // clients that were rejected in the same instant
            self.seed = self
                .seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let wait = hint.max(backoff_ms);
            let jitter = self.seed % (wait / 2 + 1);
            std::thread::sleep(Duration::from_millis(wait + jitter));
            backoff_ms = (backoff_ms * 2).min(2000);
            reply = self.request(req)?;
        }
        Ok(reply)
    }

    /// Fetch the Prometheus exposition via `METRICS`: reads the
    /// `OK lines=<N>` header, then exactly N body lines.
    pub fn metrics(&mut self) -> Result<String> {
        let header = self.request("METRICS")?;
        let n: usize = header
            .strip_prefix("OK lines=")
            .with_context(|| format!("bad METRICS header '{header}'"))?
            .parse()
            .context("bad METRICS line count")?;
        let mut body = String::new();
        let mut line = String::new();
        for _ in 0..n {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("connection closed mid-METRICS body"));
            }
            body.push_str(&line);
        }
        Ok(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exact-match STATUS field extraction: `contains("jobs=1")` would
    /// also match `jobs=10` — the old roundtrip assertion had exactly
    /// that bug and silently passed on a stale count.
    fn status_field(reply: &str, key: &str) -> String {
        reply
            .split_whitespace()
            .find_map(|f| f.strip_prefix(&format!("{key}=")))
            .unwrap_or_else(|| panic!("missing {key}= in '{reply}'"))
            .to_string()
    }

    #[test]
    fn server_decomp_roundtrip() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("DECOMP complete:n=6 algo=pkt threads=2").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        assert!(r.contains("tmax=6"), "{r}");
        // pkt peel tuning options
        let r = c
            .request("DECOMP complete:n=6 algo=pkt compact=1.0 bitsets=false")
            .unwrap();
        assert!(r.contains("tmax=6"), "{r}");
        // deep invariant checks pass on a clean pipeline
        let r = c.request("DECOMP complete:n=6 validate=true threads=2").unwrap();
        assert!(r.contains("tmax=6"), "{r}");
        // three DECOMP jobs ran on this server — count them exactly
        let r = c.request("STATUS").unwrap();
        assert_eq!(status_field(&r, "jobs"), "3", "{r}");
        h.shutdown();
    }

    #[test]
    fn server_hist() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("HIST complete:n=5").unwrap();
        assert_eq!(r, "OK 5:10");
        h.shutdown();
    }

    #[test]
    fn server_error_paths() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        assert!(c.request("FROB x").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 algo=zzz").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 bogus").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 compact=x").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 bitsets=2").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 validate=x").unwrap().starts_with("ERR"));
        // timeout= must be a finite non-negative number of seconds
        assert!(c.request("DECOMP er:n=10,p=0.1 timeout=abc").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 timeout=-1").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 timeout=nan").unwrap().starts_with("ERR"));
        // server still alive after errors
        assert!(c.request("STATUS").unwrap().starts_with("OK"));
        h.shutdown();
    }

    #[test]
    fn server_status_fields() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("STATUS").unwrap();
        assert!(r.starts_with("OK jobs=0 "), "{r}");
        assert_eq!(status_field(&r, "inflight"), "0", "{r}");
        assert_eq!(status_field(&r, "queued"), "0", "{r}");
        assert_eq!(status_field(&r, "conns"), "1", "{r}");
        assert!(r.contains("uptime_secs="), "{r}");
        assert!(r.contains("threads_default="), "{r}");
        assert!(r.contains("workers="), "{r}");
        assert!(r.contains("queue_depth="), "{r}");
        let uptime: f64 = status_field(&r, "uptime_secs").parse().unwrap();
        assert!(uptime >= 0.0);
        h.shutdown();
    }

    #[test]
    fn server_metrics_verb() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("DECOMP complete:n=5 threads=1").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        let body = c.metrics().unwrap();
        assert!(
            body.contains("server_requests_total{verb=\"DECOMP\"}"),
            "{body}"
        );
        assert!(body.contains("# TYPE server_request_seconds histogram"), "{body}");
        assert!(body.contains("phase_seconds_bucket{phase=\"pkt.peel\""), "{body}");
        // the executor's gauges register on first use
        assert!(body.contains("server_inflight_jobs"), "{body}");
        assert!(body.contains("server_queue_depth"), "{body}");
        // the connection stays usable after the multi-line reply
        assert!(c.request("STATUS").unwrap().starts_with("OK "));
        h.shutdown();
    }

    #[test]
    fn server_concurrent_clients() {
        let h = serve("127.0.0.1:0").unwrap();
        let addr = h.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let r = c
                        .request(&format!("DECOMP er:n=60,p=0.15,seed={i} threads=1"))
                        .unwrap();
                    assert!(r.starts_with("OK "), "{r}");
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        assert_eq!(h.jobs_served(), 4);
        h.shutdown();
    }

    #[test]
    fn server_timeout_option_roundtrip() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        // a generous deadline on a tiny job completes normally
        let r = c.request("DECOMP complete:n=5 threads=1 timeout=30").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        h.shutdown();
    }

    #[test]
    fn server_dynamic_verbs_roundtrip() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("LOAD g1 complete:n=5 threads=1").unwrap();
        assert!(r.starts_with("OK name=g1 "), "{r}");
        assert!(r.contains("tmax=5"), "{r}");
        // complete the 6th vertex into the clique: K5 → K6, tmax 6
        let r = c.request("INSERT g1 0-5,1-5,2-5,3-5,4-5 validate=true").unwrap();
        assert!(r.starts_with("OK op=insert "), "{r}");
        assert!(r.contains("applied=5"), "{r}");
        assert!(r.contains("tmax=6"), "{r}");
        // K6 minus one edge peels back to tmax 5
        let r = c.request("REMOVE g1 0-1 validate=true").unwrap();
        assert!(r.starts_with("OK op=remove "), "{r}");
        assert!(r.contains("tmax=5"), "{r}");
        // the resident graph shows up in STATUS, and updates count as jobs
        let r = c.request("STATUS").unwrap();
        assert_eq!(status_field(&r, "graphs"), "1", "{r}");
        assert_eq!(status_field(&r, "jobs"), "3", "{r}");
        let r = c.request("UNLOAD g1").unwrap();
        assert_eq!(r, "OK unloaded=g1");
        let r = c.request("STATUS").unwrap();
        assert_eq!(status_field(&r, "graphs"), "0", "{r}");
        h.shutdown();
    }

    #[test]
    fn server_dynamic_error_paths() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        // updates need a resident graph
        assert!(c.request("INSERT nope 0-1").unwrap().starts_with("ERR"));
        assert!(c.request("REMOVE nope 0-1").unwrap().starts_with("ERR"));
        assert!(c.request("UNLOAD nope").unwrap().starts_with("ERR"));
        // malformed requests
        assert!(c.request("LOAD").unwrap().starts_with("ERR"));
        assert!(c.request("LOAD bad/name complete:n=4").unwrap().starts_with("ERR"));
        assert!(c.request("LOAD g complete:n=4 order=xxx").unwrap().starts_with("ERR"));
        assert!(c.request("INSERT g").unwrap().starts_with("ERR"));
        let r = c.request("LOAD g complete:n=4 threads=1").unwrap();
        assert!(r.starts_with("OK name=g "), "{r}");
        assert!(c.request("INSERT g 0:1").unwrap().starts_with("ERR"));
        assert!(c.request("INSERT g 0-x").unwrap().starts_with("ERR"));
        assert!(c.request("INSERT g ,").unwrap().starts_with("ERR"));
        assert!(c.request("INSERT g 0-1 bogus=1").unwrap().starts_with("ERR"));
        assert!(c.request("INSERT g 0-1 timeout=-1").unwrap().starts_with("ERR"));
        // dirty batches are skipped-and-counted, not errors: an edge
        // already present, its duplicate, and two self-loops
        let r = c.request("INSERT g 0-1,0-0,2-2,1-0").unwrap();
        assert!(r.starts_with("OK op=insert "), "{r}");
        assert!(r.contains("applied=0"), "{r}");
        assert!(r.contains("skipped=4"), "{r}");
        // removing an absent edge is equally harmless
        let r = c.request("REMOVE g 2-9").unwrap();
        assert!(r.starts_with("OK op=remove "), "{r}");
        assert!(r.contains("applied=0"), "{r}");
        // the server is intact after all of that
        assert!(c.request("STATUS").unwrap().starts_with("OK"));
        h.shutdown();
    }
}
