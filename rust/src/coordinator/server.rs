//! A multi-client truss-analytics server over TCP (std::net,
//! thread-per-connection — tokio is not available offline).
//!
//! Line protocol (one request per line, one `OK ...` / `ERR ...` reply;
//! `METRICS` is the one multi-line reply, framed by its header):
//!
//! ```text
//! DECOMP <graphspec> [algo=pkt|wc|ros|local] [threads=N] [order=nat|deg|kco]
//!                    [compact=0.3] [bitsets=true]     (pkt peel tuning)
//!                    [validate=true]    (deep invariant checks, see crate::validate)
//! HIST    <graphspec> [...same options]   → trussness histogram
//! STATUS                                  → jobs, in-flight, uptime, threads
//! METRICS                                 → OK lines=<N> + N exposition lines
//! QUIT                                    → close this connection
//! ```
//!
//! Every request is counted, timed, and error-tracked per verb in the
//! global `obs` registry (`server_requests_total{verb=..}`,
//! `server_errors_total{verb=..}`, `server_request_seconds{verb=..}`),
//! which `METRICS` then serves back in Prometheus text format.

use super::{Algorithm, GraphSpec, JobConfig};
use crate::obs;
use crate::order::Ordering as VOrdering;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use crate::par::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

struct ServerState {
    stop: AtomicBool,
    jobs: AtomicU64,
    inflight: AtomicU64,
    started: Instant,
}

/// Handle to a running server; dropping it does NOT stop the server —
/// call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    state: Arc<ServerState>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Jobs served so far.
    pub fn jobs_served(&self) -> u64 {
        self.state.jobs.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        // ORDERING: Release pairs with the Acquire load in the accept
        // loop; the flag is the only state published through this edge,
        // so SeqCst's total order buys nothing (loom-checked pattern:
        // par::loom_model::loom_level_boundary_publish).
        self.state.stop.store(true, Ordering::Release);
        // poke the accept loop awake
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the server on `addr` (use port 0 for an ephemeral port).
/// Returns once the listener is bound.
pub fn serve(addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let state = Arc::new(ServerState {
        stop: AtomicBool::new(false),
        jobs: AtomicU64::new(0),
        inflight: AtomicU64::new(0),
        started: Instant::now(),
    });
    let accept_state = state.clone();
    let join = std::thread::spawn(move || {
        for conn in listener.incoming() {
            // ORDERING: Acquire pairs with the Release store in
            // `ServerHandle::shutdown`.
            if accept_state.stop.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let st = accept_state.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &st);
            });
        }
    });
    Ok(ServerHandle { addr: local, state, join: Some(join) })
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        let verb = canonical_verb(req);
        let m = verb_metrics(verb);
        m.requests.inc();
        let t0 = Instant::now();
        let outcome = dispatch(req, state);
        m.latency.observe(t0.elapsed().as_secs_f64());
        let reply = match outcome {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // QUIT
            Err(e) => {
                m.errors.inc();
                format!("ERR {e:#}").replace('\n', " ")
            }
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        let _ = peer;
    }
}

/// Normalize a request line to a static verb for metric labels (bounded
/// cardinality: arbitrary client input must never become a label value).
fn canonical_verb(req: &str) -> &'static str {
    let verb = req.split_whitespace().next().unwrap_or("").to_ascii_uppercase();
    match verb.as_str() {
        "DECOMP" => "DECOMP",
        "HIST" => "HIST",
        "STATUS" => "STATUS",
        "METRICS" => "METRICS",
        "QUIT" => "QUIT",
        _ => "UNKNOWN",
    }
}

struct VerbMetrics {
    requests: obs::Counter,
    errors: obs::Counter,
    latency: obs::Histogram,
}

fn verb_metrics(verb: &'static str) -> VerbMetrics {
    let r = obs::global();
    VerbMetrics {
        requests: r.counter("server_requests_total", &[("verb", verb)]),
        errors: r.counter("server_errors_total", &[("verb", verb)]),
        latency: r.histogram("server_request_seconds", &[("verb", verb)]),
    }
}

fn dispatch(req: &str, state: &ServerState) -> Result<Option<String>> {
    let mut parts = req.split_whitespace();
    let verb = parts.next().unwrap_or("").to_ascii_uppercase();
    match verb.as_str() {
        "QUIT" => Ok(None),
        "STATUS" => Ok(Some(format!(
            "OK jobs={} inflight={} uptime_secs={:.3} threads_default={}",
            state.jobs.load(Ordering::Relaxed),
            state.inflight.load(Ordering::Relaxed),
            state.started.elapsed().as_secs_f64(),
            crate::par::Pool::default_threads()
        ))),
        "METRICS" => {
            let body = obs::expo::render(obs::global());
            let mut reply = format!("OK lines={}", body.lines().count());
            for l in body.lines() {
                reply.push('\n');
                reply.push_str(l);
            }
            Ok(Some(reply))
        }
        "DECOMP" | "HIST" => {
            let spec_str = parts.next().context("missing graph spec")?;
            let cfg = parse_job(spec_str, parts)?;
            let gauge = obs::global().gauge("server_inflight_jobs", &[]);
            gauge.set(state.inflight.fetch_add(1, Ordering::Relaxed) as f64 + 1.0);
            let report = super::run_job(&cfg);
            gauge.set(state.inflight.fetch_sub(1, Ordering::Relaxed) as f64 - 1.0);
            let report = report?;
            state.jobs.fetch_add(1, Ordering::Relaxed);
            if verb == "DECOMP" {
                Ok(Some(format!("OK {}", report.summary())))
            } else {
                let hist: Vec<String> = report
                    .histogram
                    .iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(k, &c)| format!("{k}:{c}"))
                    .collect();
                Ok(Some(format!("OK {}", hist.join(","))))
            }
        }
        _ => Err(anyhow!("unknown verb '{verb}' (DECOMP|HIST|STATUS|METRICS|QUIT)")),
    }
}

fn parse_job<'a>(spec_str: &str, opts: impl Iterator<Item = &'a str>) -> Result<JobConfig> {
    let spec = GraphSpec::parse(spec_str)?;
    let mut cfg = JobConfig::new(spec);
    for opt in opts {
        let (k, v) = opt.split_once('=').with_context(|| format!("bad option '{opt}'"))?;
        match k {
            "algo" => cfg.algorithm = Algorithm::parse(v)?,
            "threads" => cfg.threads = v.parse().context("bad threads")?,
            "order" => {
                cfg.ordering =
                    VOrdering::parse(v).with_context(|| format!("bad order '{v}'"))?
            }
            "compact" => {
                cfg.pkt.compact_threshold = v.parse().context("bad compact threshold")?
            }
            "bitsets" => cfg.pkt.use_bitsets = v.parse().context("bad bitsets flag")?,
            "validate" => cfg.validate = v.parse().context("bad validate flag")?,
            _ => return Err(anyhow!("unknown option '{k}'")),
        }
    }
    Ok(cfg)
}

/// Small blocking client for the line protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request line, read one reply line.
    pub fn request(&mut self, req: &str) -> Result<String> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim_end().to_string())
    }

    /// Fetch the Prometheus exposition via `METRICS`: reads the
    /// `OK lines=<N>` header, then exactly N body lines.
    pub fn metrics(&mut self) -> Result<String> {
        let header = self.request("METRICS")?;
        let n: usize = header
            .strip_prefix("OK lines=")
            .with_context(|| format!("bad METRICS header '{header}'"))?
            .parse()
            .context("bad METRICS line count")?;
        let mut body = String::new();
        let mut line = String::new();
        for _ in 0..n {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("connection closed mid-METRICS body"));
            }
            body.push_str(&line);
        }
        Ok(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_decomp_roundtrip() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("DECOMP complete:n=6 algo=pkt threads=2").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        assert!(r.contains("tmax=6"), "{r}");
        // pkt peel tuning options
        let r = c
            .request("DECOMP complete:n=6 algo=pkt compact=1.0 bitsets=false")
            .unwrap();
        assert!(r.contains("tmax=6"), "{r}");
        // deep invariant checks pass on a clean pipeline
        let r = c.request("DECOMP complete:n=6 validate=true threads=2").unwrap();
        assert!(r.contains("tmax=6"), "{r}");
        let r = c.request("STATUS").unwrap();
        assert!(r.contains("jobs=1"), "{r}");
        h.shutdown();
    }

    #[test]
    fn server_hist() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("HIST complete:n=5").unwrap();
        assert_eq!(r, "OK 5:10");
        h.shutdown();
    }

    #[test]
    fn server_error_paths() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        assert!(c.request("FROB x").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 algo=zzz").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 bogus").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 compact=x").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 bitsets=2").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 validate=x").unwrap().starts_with("ERR"));
        // server still alive after errors
        assert!(c.request("STATUS").unwrap().starts_with("OK"));
        h.shutdown();
    }

    #[test]
    fn server_status_fields() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("STATUS").unwrap();
        assert!(r.starts_with("OK jobs=0 "), "{r}");
        assert!(r.contains("inflight=0"), "{r}");
        assert!(r.contains("uptime_secs="), "{r}");
        assert!(r.contains("threads_default="), "{r}");
        let uptime: f64 = r
            .split_whitespace()
            .find_map(|f| f.strip_prefix("uptime_secs="))
            .unwrap()
            .parse()
            .unwrap();
        assert!(uptime >= 0.0);
        h.shutdown();
    }

    #[test]
    fn server_metrics_verb() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("DECOMP complete:n=5 threads=1").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        let body = c.metrics().unwrap();
        assert!(
            body.contains("server_requests_total{verb=\"DECOMP\"}"),
            "{body}"
        );
        assert!(body.contains("# TYPE server_request_seconds histogram"), "{body}");
        assert!(body.contains("phase_seconds_bucket{phase=\"pkt.peel\""), "{body}");
        // the connection stays usable after the multi-line reply
        assert!(c.request("STATUS").unwrap().starts_with("OK "));
        h.shutdown();
    }

    #[test]
    fn server_concurrent_clients() {
        let h = serve("127.0.0.1:0").unwrap();
        let addr = h.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let r = c
                        .request(&format!("DECOMP er:n=60,p=0.15,seed={i} threads=1"))
                        .unwrap();
                    assert!(r.starts_with("OK "), "{r}");
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        assert_eq!(h.jobs_served(), 4);
        h.shutdown();
    }
}
