//! A multi-client truss-analytics server over TCP (std::net,
//! thread-per-connection — tokio is not available offline).
//!
//! Line protocol (one request per line, one `OK ...` / `ERR ...` reply):
//!
//! ```text
//! DECOMP <graphspec> [algo=pkt|wc|ros|local] [threads=N] [order=nat|deg|kco]
//! HIST   <graphspec> [...same options]       → trussness histogram
//! STATUS                                      → jobs served, platform
//! QUIT                                        → close this connection
//! ```

use super::{Algorithm, GraphSpec, JobConfig};
use crate::order::Ordering as VOrdering;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

struct ServerState {
    stop: AtomicBool,
    jobs: AtomicU64,
}

/// Handle to a running server; dropping it does NOT stop the server —
/// call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    state: Arc<ServerState>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Jobs served so far.
    pub fn jobs_served(&self) -> u64 {
        self.state.jobs.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // poke the accept loop awake
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the server on `addr` (use port 0 for an ephemeral port).
/// Returns once the listener is bound.
pub fn serve(addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr()?;
    let state = Arc::new(ServerState { stop: AtomicBool::new(false), jobs: AtomicU64::new(0) });
    let accept_state = state.clone();
    let join = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_state.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let st = accept_state.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(stream, &st);
            });
        }
    });
    Ok(ServerHandle { addr: local, state, join: Some(join) })
}

fn handle_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let req = line.trim();
        if req.is_empty() {
            continue;
        }
        let reply = match dispatch(req, state) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // QUIT
            Err(e) => format!("ERR {e:#}").replace('\n', " "),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        let _ = peer;
    }
}

fn dispatch(req: &str, state: &ServerState) -> Result<Option<String>> {
    let mut parts = req.split_whitespace();
    let verb = parts.next().unwrap_or("").to_ascii_uppercase();
    match verb.as_str() {
        "QUIT" => Ok(None),
        "STATUS" => Ok(Some(format!(
            "OK jobs={} threads_default={}",
            state.jobs.load(Ordering::Relaxed),
            crate::par::Pool::default_threads()
        ))),
        "DECOMP" | "HIST" => {
            let spec_str = parts.next().context("missing graph spec")?;
            let cfg = parse_job(spec_str, parts)?;
            let report = super::run_job(&cfg)?;
            state.jobs.fetch_add(1, Ordering::Relaxed);
            if verb == "DECOMP" {
                Ok(Some(format!("OK {}", report.summary())))
            } else {
                let hist: Vec<String> = report
                    .histogram
                    .iter()
                    .enumerate()
                    .filter(|&(_, &c)| c > 0)
                    .map(|(k, &c)| format!("{k}:{c}"))
                    .collect();
                Ok(Some(format!("OK {}", hist.join(","))))
            }
        }
        _ => Err(anyhow!("unknown verb '{verb}' (DECOMP|HIST|STATUS|QUIT)")),
    }
}

fn parse_job<'a>(spec_str: &str, opts: impl Iterator<Item = &'a str>) -> Result<JobConfig> {
    let spec = GraphSpec::parse(spec_str)?;
    let mut cfg = JobConfig::new(spec);
    for opt in opts {
        let (k, v) = opt.split_once('=').with_context(|| format!("bad option '{opt}'"))?;
        match k {
            "algo" => cfg.algorithm = Algorithm::parse(v)?,
            "threads" => cfg.threads = v.parse().context("bad threads")?,
            "order" => {
                cfg.ordering =
                    VOrdering::parse(v).with_context(|| format!("bad order '{v}'"))?
            }
            _ => return Err(anyhow!("unknown option '{k}'")),
        }
    }
    Ok(cfg)
}

/// Small blocking client for the line protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request line, read one reply line.
    pub fn request(&mut self, req: &str) -> Result<String> {
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_decomp_roundtrip() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("DECOMP complete:n=6 algo=pkt threads=2").unwrap();
        assert!(r.starts_with("OK "), "{r}");
        assert!(r.contains("tmax=6"), "{r}");
        let r = c.request("STATUS").unwrap();
        assert!(r.contains("jobs=1"), "{r}");
        h.shutdown();
    }

    #[test]
    fn server_hist() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        let r = c.request("HIST complete:n=5").unwrap();
        assert_eq!(r, "OK 5:10");
        h.shutdown();
    }

    #[test]
    fn server_error_paths() {
        let h = serve("127.0.0.1:0").unwrap();
        let mut c = Client::connect(h.addr).unwrap();
        assert!(c.request("FROB x").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 algo=zzz").unwrap().starts_with("ERR"));
        assert!(c.request("DECOMP er:n=10,p=0.1 bogus").unwrap().starts_with("ERR"));
        // server still alive after errors
        assert!(c.request("STATUS").unwrap().starts_with("OK"));
        h.shutdown();
    }

    #[test]
    fn server_concurrent_clients() {
        let h = serve("127.0.0.1:0").unwrap();
        let addr = h.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let r = c
                        .request(&format!("DECOMP er:n=60,p=0.15,seed={i} threads=1"))
                        .unwrap();
                    assert!(r.starts_with("OK "), "{r}");
                })
            })
            .collect();
        for th in handles {
            th.join().unwrap();
        }
        assert_eq!(h.jobs_served(), 4);
        h.shutdown();
    }
}
