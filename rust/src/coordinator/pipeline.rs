//! The decomposition pipeline: graph acquisition → preprocessing
//! (ordering) → truss decomposition → report.

use super::{Algorithm, JobConfig};
use crate::graph::EdgeGraph;
use crate::metrics::{gweps, Timer};
use crate::order;
use crate::par::{CancelToken, Pool};
use crate::truss::{self, PktStats};
use crate::{triangle, validate};
use anyhow::{bail, Result};

/// Everything a job run produces. Per-edge trussness is kept alongside
/// the summary so callers (server, examples) can drill in.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub graph_desc: String,
    pub algorithm: &'static str,
    pub ordering: &'static str,
    pub threads: usize,
    pub n: usize,
    pub m: usize,
    pub wedges: u64,
    pub t_max: u32,
    /// Trussness histogram: `hist[k]` = edges of trussness k.
    pub histogram: Vec<u64>,
    /// Per-edge trussness (edge ids of the *reordered* graph).
    pub trussness: Vec<u32>,
    pub build_secs: f64,
    pub order_secs: f64,
    pub decompose_secs: f64,
    /// Wall time spent in the pre/post validation passes (0 when
    /// validation is off; excludes the peel's in-place compaction checks,
    /// which land inside `decompose_secs`).
    pub validate_secs: f64,
    /// Phase breakdown from the decomposition.
    pub stats: PktStats,
    /// Wedges/sec/1e9 over the decomposition time (the paper's rate).
    pub gweps: f64,
}

impl JobReport {
    /// One-line summary (server protocol + CLI output).
    pub fn summary(&self) -> String {
        format!(
            "graph={} algo={} order={} threads={} n={} m={} wedges={} tmax={} decomp_secs={:.4} gweps={:.4}",
            self.graph_desc,
            self.algorithm,
            self.ordering,
            self.threads,
            self.n,
            self.m,
            self.wedges,
            self.t_max,
            self.decompose_secs,
            self.gweps
        )
    }
}

/// Run a job end to end (no cancellation — an inert token).
pub fn run_job(cfg: &JobConfig) -> Result<JobReport> {
    run_job_with(cfg, &CancelToken::never())
}

/// Return early with a [`crate::par::Cancelled`] error if the token has
/// fired. Used between pipeline phases; within a phase the decomposition
/// polls the token at its own level/chunk boundaries.
fn checkpoint(token: &CancelToken, at: &'static str) -> Result<()> {
    if token.should_stop().is_some() {
        return Err(token.stopped(at, String::new()).into());
    }
    Ok(())
}

/// [`run_job`] with cooperative cancellation. The token is polled at
/// phase boundaries here and inside the support/peel loops; on stop the
/// error downcasts to [`crate::par::Cancelled`] with partial progress.
pub fn run_job_with(cfg: &JobConfig, token: &CancelToken) -> Result<JobReport> {
    let t_build = Timer::start();
    let g0 = cfg.graph.build()?;
    let build_secs = t_build.secs();
    checkpoint(token, "pipeline.build")?;

    let t_order = Timer::start();
    let (g, _perm) = order::reorder(&g0, cfg.ordering);
    drop(g0);
    let eg = EdgeGraph::new(g);
    let order_secs = t_order.secs();

    let pool = Pool::new(cfg.threads);

    // validation, part 1: structural pre-checks on the inputs the
    // decomposition trusts. The scoped guard also arms the peel's
    // in-place compaction checks for the duration of the job.
    let validating = cfg.validate || validate::enabled();
    let _vguard = validating.then(validate::enable_scoped);
    let mut validate_secs = 0.0;
    if validating {
        let t_val = Timer::start();
        let mut rep = validate::Report::new();
        validate::check_graph(&eg.g, &mut rep);
        validate::check_edge_graph(&eg, &mut rep);
        let s = triangle::into_plain(triangle::support_am4_with(&eg, &pool, token)?);
        validate::check_support(&eg, &s, &mut rep);
        if let Some(err) = rep.error() {
            bail!("pre-decomposition validation failed:\n{err}");
        }
        validate_secs = t_val.secs();
    }

    checkpoint(token, "pipeline.decompose")?;
    let t_dec = Timer::start();
    let result = match cfg.algorithm {
        // PKT threads the token all the way into the peel's level loop;
        // the serial/baseline algorithms only honor the phase boundary
        // above (they have no natural sync points to poll at).
        Algorithm::Pkt => truss::pkt_config_with(&eg, &pool, &cfg.pkt, token)?,
        Algorithm::Wc => truss::wc(&eg),
        Algorithm::Ros => truss::ros(&eg, &pool),
        Algorithm::Local => truss::local(&eg, &pool, 100_000),
    };
    let decompose_secs = t_dec.secs();

    // validation, part 2: the output against its analytic bounds
    if validating {
        let t_post = Timer::start();
        let mut rep = validate::Report::new();
        validate::check_trussness(&eg, &result.trussness, &mut rep);
        if let Some(err) = rep.error() {
            bail!("post-decomposition validation failed:\n{err}");
        }
        validate_secs += t_post.secs();
    }

    let wedges = eg.g.wedge_count();
    Ok(JobReport {
        graph_desc: cfg.graph.describe(),
        algorithm: cfg.algorithm.name(),
        ordering: cfg.ordering.name(),
        threads: cfg.threads,
        n: eg.n(),
        m: eg.m(),
        wedges,
        t_max: truss::max_trussness(&result.trussness),
        histogram: truss::class_histogram(&result.trussness),
        trussness: result.trussness,
        build_secs,
        order_secs,
        decompose_secs,
        validate_secs,
        stats: result.stats,
        gweps: gweps(wedges, decompose_secs),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GraphSpec;

    #[test]
    fn pipeline_complete_graph() {
        let cfg = JobConfig::new(GraphSpec::Complete { n: 8 }).threads(2);
        let r = run_job(&cfg).unwrap();
        assert_eq!(r.n, 8);
        assert_eq!(r.m, 28);
        assert_eq!(r.t_max, 8);
        assert_eq!(r.histogram[8], 28);
        assert!(r.decompose_secs > 0.0);
    }

    #[test]
    fn pipeline_all_algorithms_agree() {
        let spec = GraphSpec::parse("pp:blocks=3,size=12,pin=0.8,pout=0.02,seed=5").unwrap();
        let base = run_job(&JobConfig::new(spec.clone()).threads(2)).unwrap();
        for algo in [Algorithm::Wc, Algorithm::Ros, Algorithm::Local] {
            let r = run_job(&JobConfig::new(spec.clone()).algorithm(algo).threads(2)).unwrap();
            assert_eq!(r.trussness, base.trussness, "{}", algo.name());
            assert_eq!(r.t_max, base.t_max);
        }
    }

    #[test]
    fn pipeline_orderings_preserve_histogram() {
        let spec = GraphSpec::parse("rmat:n=256,m=1500,seed=3").unwrap();
        let mut hists = vec![];
        for ord in [
            crate::order::Ordering::Natural,
            crate::order::Ordering::Degree,
            crate::order::Ordering::KCore,
        ] {
            let r = run_job(&JobConfig::new(spec.clone()).ordering(ord).threads(2)).unwrap();
            hists.push(r.histogram);
        }
        assert_eq!(hists[0], hists[1]);
        assert_eq!(hists[0], hists[2]);
    }

    #[test]
    fn pipeline_validate_clean_run() {
        // rmat + default pkt config triggers compaction rebuilds, so the
        // in-peel check_compaction hook runs too (scoped enable)
        let spec = GraphSpec::parse("rmat:n=256,m=1500,seed=3").unwrap();
        let r = run_job(&JobConfig::new(spec).threads(2).validate(true)).unwrap();
        assert!(r.validate_secs > 0.0, "validation time must be recorded");
        let base_spec = GraphSpec::parse("rmat:n=256,m=1500,seed=3").unwrap();
        let base = run_job(&JobConfig::new(base_spec).threads(2)).unwrap();
        assert_eq!(base.validate_secs, 0.0, "no validation time when off");
        assert_eq!(r.trussness, base.trussness, "validation must not perturb results");
    }

    #[test]
    fn pipeline_cancellation_downcasts() {
        let spec = GraphSpec::parse("er:n=300,p=0.05,seed=9").unwrap();
        let token = CancelToken::with_timeout(Some(std::time::Duration::ZERO));
        let err = run_job_with(&JobConfig::new(spec).threads(2), &token).unwrap_err();
        let c = err
            .downcast_ref::<crate::par::Cancelled>()
            .expect("cancellation must surface as a typed Cancelled error");
        assert_eq!(c.reason.name(), "DEADLINE");
    }

    #[test]
    fn summary_contains_fields() {
        let cfg = JobConfig::new(GraphSpec::Complete { n: 5 }).threads(1);
        let s = run_job(&cfg).unwrap().summary();
        assert!(s.contains("algo=pkt"));
        assert!(s.contains("tmax=5"));
    }
}
