//! Bounded job executor: admission control, deadlines, cancellation,
//! graceful drain.
//!
//! The server used to spawn one decomposition per request with no
//! ceiling — N slow clients meant N concurrent peels fighting over the
//! same cores. This module replaces that with a fixed worker pool in
//! front of a bounded queue:
//!
//! - [`Executor::submit`] is **non-blocking** admission: a full queue
//!   returns [`SubmitError::Busy`] with a load-derived `retry_after_ms`
//!   hint instead of stacking threads;
//! - every job gets a [`CancelToken`]; a per-job `timeout=` (or the
//!   executor-wide default) arms a deadline the decomposition polls at
//!   its level/chunk boundaries;
//! - worker panics are caught and isolated ([`std::panic::catch_unwind`])
//!   — the client sees `ERR internal ...`, the worker keeps serving;
//! - [`Executor::shutdown`] stops admissions, waits for in-flight and
//!   queued jobs up to a drain deadline, then cancels stragglers via
//!   their tokens and joins the pool.
//!
//! In-flight accounting is RAII ([`InflightGuard`]) so the counter and
//! its gauge can't leak on any exit path, and the gauges are derived
//! from an atomic load *after* the RMW — publishing `fetch_add(..) + 1`
//! arithmetic is racy under concurrent updates.
//!
//! Fault injection for tests: `TRUSSX_FAULT=<point>:<delay_ms|panic|err>`
//! (or [`ExecutorConfig::fault`] directly, which avoids env races in
//! parallel tests) fires at named points; the only point today is
//! `job.start`, hit by every worker just before the pipeline runs.

use super::config::JobConfig;
use super::pipeline::{run_job_with, JobReport};
use crate::obs;
use crate::par::sync::atomic::{AtomicU64, Ordering};
use crate::par::{CancelReason, CancelToken, Cancelled};
use crate::truss::UpdateReport;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What an injected fault does when its point is hit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Sleep this long (in small slices, honoring the job's token).
    Delay(Duration),
    /// Panic — exercises the worker's panic isolation.
    Panic,
    /// Return an error from the job.
    Err,
}

/// A parsed `TRUSSX_FAULT=<point>:<delay_ms|panic|err>` spec.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub point: String,
    pub action: FaultAction,
}

impl FaultSpec {
    /// Parse `point:action` where action is a delay in ms, `panic`, or
    /// `err`.
    pub fn parse(s: &str) -> Result<Self> {
        let (point, action) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("bad fault spec '{s}' (want point:delay_ms|panic|err)"))?;
        if point.is_empty() {
            bail!("bad fault spec '{s}': empty point");
        }
        let action = match action {
            "panic" => FaultAction::Panic,
            "err" => FaultAction::Err,
            ms => FaultAction::Delay(Duration::from_millis(
                ms.parse().map_err(|_| anyhow!("bad fault delay '{ms}' (want ms|panic|err)"))?,
            )),
        };
        Ok(Self { point: point.to_string(), action })
    }

    /// Read `TRUSSX_FAULT` from the environment; a malformed spec is
    /// reported and ignored rather than silently arming nothing-like.
    pub fn from_env() -> Option<Self> {
        let spec = std::env::var("TRUSSX_FAULT").ok()?;
        match Self::parse(&spec) {
            Ok(f) => Some(f),
            Err(e) => {
                eprintln!("ignoring TRUSSX_FAULT: {e:#}");
                None
            }
        }
    }

    /// Fire if `point` matches. Delays sleep in ≤5ms slices so a cancel
    /// or deadline interrupts the fault promptly.
    fn fire(&self, point: &str, token: &CancelToken) -> Result<()> {
        if self.point != point {
            return Ok(());
        }
        match &self.action {
            FaultAction::Delay(d) => {
                let until = Instant::now() + *d;
                loop {
                    if token.should_stop().is_some() {
                        return Err(token.stopped("fault.delay", format!("at {point}")).into());
                    }
                    let now = Instant::now();
                    if now >= until {
                        return Ok(());
                    }
                    std::thread::sleep((until - now).min(Duration::from_millis(5)));
                }
            }
            FaultAction::Panic => panic!("injected fault at {point}"),
            FaultAction::Err => bail!("injected fault at {point}"),
        }
    }
}

/// Executor sizing and policy.
#[derive(Clone, Debug)]
pub struct ExecutorConfig {
    /// Worker threads (concurrent jobs). Each job still parallelizes
    /// internally through its own [`crate::par::Pool`].
    pub workers: usize,
    /// Bounded queue depth; a full queue rejects with `ERR BUSY`.
    pub queue_depth: usize,
    /// Default per-job deadline; a job's own `timeout=` overrides it.
    pub job_timeout: Option<Duration>,
    /// Fault injection point (tests); defaults from `TRUSSX_FAULT`.
    pub fault: Option<FaultSpec>,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        Self { workers: 2, queue_depth: 16, job_timeout: None, fault: FaultSpec::from_env() }
    }
}

/// Why [`Executor::submit`] refused a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is full; retry after roughly this many milliseconds
    /// (average job time × queue occupancy / workers).
    Busy { retry_after_ms: u64 },
    /// [`Executor::shutdown`] has begun; no new jobs.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Busy { retry_after_ms } => {
                write!(f, "BUSY retry_after_ms={retry_after_ms}")
            }
            Self::ShuttingDown => write!(f, "SHUTDOWN draining"),
        }
    }
}

/// What a finished job produced. The executor used to be hardwired to
/// decomposition pipelines; the dynamic-maintenance verbs (LOAD /
/// INSERT / REMOVE) run arbitrary closures through the same admission
/// control, deadlines and drain, so the reply channel carries a sum
/// type instead of a [`JobReport`].
#[derive(Debug)]
pub enum JobOutcome {
    /// A full decomposition ([`Executor::submit`] / DECOMP / HIST).
    Decomp(JobReport),
    /// A batch-dynamic update (INSERT / REMOVE).
    Update(UpdateReport),
    /// A named graph was decomposed and registered (LOAD).
    Load(LoadReport),
}

/// Summary of a LOAD job: the named graph is now resident server-side.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub t_max: u32,
}

impl JobOutcome {
    /// Unwrap a decomposition outcome; errors on any other variant
    /// (a protocol-level bug, not a user fault).
    pub fn decomp(self) -> Result<JobReport> {
        match self {
            Self::Decomp(r) => Ok(r),
            other => Err(anyhow!("internal: expected Decomp outcome, got {other:?}")),
        }
    }

    /// Unwrap an update outcome.
    pub fn update(self) -> Result<UpdateReport> {
        match self {
            Self::Update(r) => Ok(r),
            other => Err(anyhow!("internal: expected Update outcome, got {other:?}")),
        }
    }

    /// Unwrap a load outcome.
    pub fn load(self) -> Result<LoadReport> {
        match self {
            Self::Load(r) => Ok(r),
            other => Err(anyhow!("internal: expected Load outcome, got {other:?}")),
        }
    }
}

/// A queued unit of work: any cancellable closure producing an outcome.
pub type JobFn = Box<dyn FnOnce(&CancelToken) -> Result<JobOutcome> + Send + 'static>;

struct Job {
    id: u64,
    run: JobFn,
    token: CancelToken,
    reply: std::sync::mpsc::Sender<Result<JobOutcome>>,
}

struct ExecShared {
    inflight: AtomicU64,
    queued: AtomicU64,
    /// Tokens of all admitted-but-unfinished jobs (queued included), so
    /// a drain-deadline cancel reaches jobs that never started.
    active: Mutex<HashMap<u64, CancelToken>>,
    /// EWMA of successful job wall time, feeding `retry_after_ms`.
    avg_job_ms: AtomicU64,
    workers: u64,
    fault: Option<FaultSpec>,
}

struct ExecMetrics {
    rejected: obs::Counter,
    timeouts: obs::Counter,
    cancelled: obs::Counter,
    inflight_gauge: obs::Gauge,
    queue_gauge: obs::Gauge,
}

fn exec_metrics() -> ExecMetrics {
    let r = obs::global();
    ExecMetrics {
        rejected: r.counter("server_rejected_total", &[]),
        timeouts: r.counter("server_timeouts_total", &[]),
        cancelled: r.counter("server_cancelled_total", &[]),
        inflight_gauge: r.gauge("server_inflight_jobs", &[]),
        queue_gauge: r.gauge("server_queue_depth", &[]),
    }
}

/// RAII in-flight accounting: increment on entry, decrement on *any*
/// exit — including a panic unwinding through the job body. The old
/// inline bookkeeping leaked the counter (and wedged the gauge) when
/// `run_job` panicked.
struct InflightGuard<'a> {
    shared: &'a ExecShared,
    gauge: obs::Gauge,
}

impl<'a> InflightGuard<'a> {
    fn enter(shared: &'a ExecShared, gauge: obs::Gauge) -> Self {
        shared.inflight.fetch_add(1, Ordering::Relaxed);
        // the gauge mirrors the counter via a load *after* the RMW;
        // publishing `fetch_add(..) + 1` arithmetic instead can expose
        // stale values when two workers race the set
        gauge.set(shared.inflight.load(Ordering::Relaxed) as f64);
        Self { shared, gauge }
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
        self.gauge.set(self.shared.inflight.load(Ordering::Relaxed) as f64);
    }
}

/// Fixed worker pool with bounded admission. See the module docs.
pub struct Executor {
    /// `None` once shutdown begins: dropping the sender is what lets
    /// workers drain the queue and exit.
    tx: Mutex<Option<SyncSender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    shared: Arc<ExecShared>,
    next_id: AtomicU64,
    job_timeout: Option<Duration>,
}

/// A submitted job: [`JobTicket::wait`] blocks for the reply,
/// [`JobTicket::cancel`] asks the job to stop at its next boundary.
pub struct JobTicket {
    rx: std::sync::mpsc::Receiver<Result<JobOutcome>>,
    token: CancelToken,
    pub id: u64,
}

impl JobTicket {
    pub fn wait(self) -> Result<JobOutcome> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(anyhow!("internal: worker dropped the job reply")),
        }
    }

    /// [`wait`](Self::wait) narrowed to a decomposition job.
    pub fn wait_decomp(self) -> Result<JobReport> {
        self.wait().and_then(JobOutcome::decomp)
    }

    pub fn cancel(&self) {
        self.token.cancel();
    }
}

impl Executor {
    pub fn new(cfg: &ExecutorConfig) -> Self {
        let workers = cfg.workers.max(1);
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth.max(1));
        // the receiver is shared; the lock serializes only job *pickup*
        // (a recv), never execution
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(ExecShared {
            inflight: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            active: Mutex::new(HashMap::new()),
            avg_job_ms: AtomicU64::new(50),
            workers: workers as u64,
            fault: cfg.fault.clone(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = rx.clone();
            let sh = shared.clone();
            // SPAWN: fixed pool sized by ExecutorConfig::workers,
            // joined in shutdown(); exits when the channel disconnects.
            let builder = std::thread::Builder::new().name(format!("trussx-worker-{i}"));
            match builder.spawn(move || worker_loop(&rx, &sh)) {
                Ok(h) => handles.push(h),
                Err(e) => panic!("spawning executor worker {i}: {e}"),
            }
        }
        Self {
            tx: Mutex::new(Some(tx)),
            handles: Mutex::new(handles),
            shared,
            next_id: AtomicU64::new(1),
            job_timeout: cfg.job_timeout,
        }
    }

    /// Non-blocking admission for a decomposition. `Ok` means the job
    /// is queued and WILL be answered through the ticket (success,
    /// error, or cancellation).
    pub fn submit(&self, cfg: JobConfig) -> Result<JobTicket, SubmitError> {
        let timeout = cfg.timeout;
        self.submit_fn(
            timeout,
            Box::new(move |token| run_job_with(&cfg, token).map(JobOutcome::Decomp)),
        )
    }

    /// Admission for an arbitrary cancellable closure — the dynamic
    /// verbs (LOAD / INSERT / REMOVE) share the bounded queue, deadline,
    /// drain and BUSY semantics with decompositions through this path.
    /// `timeout_secs` overrides the executor-wide default like a job's
    /// `timeout=` option does.
    pub fn submit_fn(
        &self,
        timeout_secs: Option<f64>,
        run: JobFn,
    ) -> Result<JobTicket, SubmitError> {
        // sanitize before Duration::from_secs_f64, which panics on
        // negative/NaN/huge input; the protocol layer validates too but
        // the executor must not trust its callers that far
        let timeout = timeout_secs
            .filter(|t| t.is_finite() && *t >= 0.0)
            .map(|t| Duration::from_secs_f64(t.min(31_536_000.0)))
            .or(self.job_timeout);
        let token = CancelToken::with_timeout(timeout);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let job = Job { id, run, token: token.clone(), reply: reply_tx };
        let m = exec_metrics();

        // register the token before enqueueing so a drain-time
        // cancel-all covers jobs that are still queued
        if let Ok(mut map) = self.shared.active.lock() {
            map.insert(id, token.clone());
        }
        // count the job as queued BEFORE try_send: the worker's
        // decrement must never run before our increment or the counter
        // underflows
        self.shared.queued.fetch_add(1, Ordering::Relaxed);
        let sent = match self.tx.lock() {
            Ok(guard) => match guard.as_ref() {
                None => Err(SubmitError::ShuttingDown),
                Some(tx) => match tx.try_send(job) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Full(_)) => {
                        Err(SubmitError::Busy { retry_after_ms: self.retry_hint() })
                    }
                    Err(TrySendError::Disconnected(_)) => Err(SubmitError::ShuttingDown),
                },
            },
            Err(_) => Err(SubmitError::ShuttingDown),
        };
        match sent {
            Ok(()) => {
                m.queue_gauge.set(self.shared.queued.load(Ordering::Relaxed) as f64);
                Ok(JobTicket { rx: reply_rx, token, id })
            }
            Err(e) => {
                self.shared.queued.fetch_sub(1, Ordering::Relaxed);
                m.queue_gauge.set(self.shared.queued.load(Ordering::Relaxed) as f64);
                if let Ok(mut map) = self.shared.active.lock() {
                    map.remove(&id);
                }
                if matches!(e, SubmitError::Busy { .. }) {
                    m.rejected.inc();
                }
                Err(e)
            }
        }
    }

    /// Load-derived backoff hint: average job time × jobs ahead of you,
    /// spread over the pool, clamped to something a client can act on.
    fn retry_hint(&self) -> u64 {
        let avg = self.shared.avg_job_ms.load(Ordering::Relaxed).max(1);
        let waiting = self.shared.queued.load(Ordering::Relaxed).max(1);
        (avg.saturating_mul(waiting) / self.shared.workers.max(1)).clamp(10, 5000)
    }

    pub fn inflight(&self) -> u64 {
        self.shared.inflight.load(Ordering::Relaxed)
    }

    pub fn queued(&self) -> u64 {
        self.shared.queued.load(Ordering::Relaxed)
    }

    /// Graceful drain: stop admissions, wait for in-flight + queued
    /// jobs up to `drain`, then cancel stragglers through their tokens
    /// and join the pool. Idempotent.
    pub fn shutdown(&self, drain: Duration) {
        // dropping the sender closes the channel: workers finish the
        // queued backlog, then exit on disconnect
        if let Ok(mut tx) = self.tx.lock() {
            tx.take();
        }
        let deadline = Instant::now() + drain;
        loop {
            let busy = self.shared.inflight.load(Ordering::Relaxed)
                + self.shared.queued.load(Ordering::Relaxed);
            if busy == 0 || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // past the drain deadline (or already idle): cancel whatever is
        // left — running jobs stop at their next boundary, queued jobs
        // stop at their first
        if let Ok(map) = self.shared.active.lock() {
            for token in map.values() {
                token.cancel();
            }
        }
        if let Ok(mut hs) = self.handles.lock() {
            for h in hs.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>, shared: &ExecShared) {
    loop {
        let job = {
            let Ok(guard) = rx.lock() else { return };
            match guard.recv() {
                Ok(j) => j,
                // queue empty and sender dropped: shutdown
                Err(_) => return,
            }
        };
        run_one(job, shared);
    }
}

fn run_one(job: Job, shared: &ExecShared) {
    let Job { id, run, token, reply } = job;
    let m = exec_metrics();
    // inflight up BEFORE queued down, so `inflight + queued` (the drain
    // condition) never dips to zero while this job is between states
    let guard = InflightGuard::enter(shared, m.inflight_gauge.clone());
    shared.queued.fetch_sub(1, Ordering::Relaxed);
    m.queue_gauge.set(shared.queued.load(Ordering::Relaxed) as f64);

    let t0 = Instant::now();
    let caught = catch_unwind(AssertUnwindSafe(|| {
        if let Some(f) = &shared.fault {
            f.fire("job.start", &token)?;
        }
        run(&token)
    }));
    drop(guard);
    let result = match caught {
        Ok(r) => r,
        Err(p) => Err(anyhow!("internal: job panicked: {}", panic_message(p.as_ref()))),
    };

    match &result {
        Ok(_) => {
            // EWMA over successes only — failed jobs return fast and
            // would drag the retry hint toward zero
            let ms = (t0.elapsed().as_millis() as u64).max(1);
            let old = shared.avg_job_ms.load(Ordering::Relaxed);
            shared.avg_job_ms.store((3 * old + ms) / 4, Ordering::Relaxed);
        }
        Err(e) => {
            if let Some(c) = e.downcast_ref::<Cancelled>() {
                match c.reason {
                    CancelReason::Deadline => m.timeouts.inc(),
                    CancelReason::Cancelled => m.cancelled.inc(),
                }
            }
        }
    }
    if let Ok(mut map) = shared.active.lock() {
        map.remove(&id);
    }
    // the ticket may already be gone (client hung up); that's fine
    let _ = reply.send(result);
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GraphSpec;

    fn quiet_cfg(workers: usize, queue_depth: usize) -> ExecutorConfig {
        // explicit fault field: tests must not read TRUSSX_FAULT, env
        // mutation races across the parallel test harness
        ExecutorConfig { workers, queue_depth, job_timeout: None, fault: None }
    }

    fn job(spec: &str) -> JobConfig {
        JobConfig::new(GraphSpec::parse(spec).unwrap()).threads(1)
    }

    #[test]
    fn fault_spec_parses() {
        assert_eq!(
            FaultSpec::parse("job.start:200").unwrap(),
            FaultSpec {
                point: "job.start".into(),
                action: FaultAction::Delay(Duration::from_millis(200))
            }
        );
        assert_eq!(FaultSpec::parse("x:panic").unwrap().action, FaultAction::Panic);
        assert_eq!(FaultSpec::parse("x:err").unwrap().action, FaultAction::Err);
        assert!(FaultSpec::parse("noaction").is_err());
        assert!(FaultSpec::parse(":5").is_err());
        assert!(FaultSpec::parse("x:fast").is_err());
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let ex = Executor::new(&quiet_cfg(1, 4));
        let t = ex.submit(job("complete:n=5")).unwrap();
        let r = t.wait_decomp().unwrap();
        assert_eq!(r.t_max, 5);
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn submit_fn_runs_arbitrary_outcomes() {
        let ex = Executor::new(&quiet_cfg(1, 4));
        let t = ex
            .submit_fn(
                None,
                Box::new(|_tok| {
                    Ok(JobOutcome::Load(LoadReport { name: "g".into(), n: 3, m: 2, t_max: 2 }))
                }),
            )
            .unwrap();
        let l = t.wait().unwrap().load().unwrap();
        assert_eq!((l.name.as_str(), l.n, l.m, l.t_max), ("g", 3, 2, 2));
        // variant mismatch surfaces as an internal error, never a panic
        let t2 = ex.submit(job("complete:n=4")).unwrap();
        assert!(t2.wait().unwrap().update().is_err());
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn full_queue_rejects_busy() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:100").unwrap()),
            ..quiet_cfg(1, 1)
        };
        let ex = Executor::new(&cfg);
        // worker occupied by #1 (or #1 still queued); by #3 the
        // depth-1 queue must be full either way
        let tickets: Vec<_> =
            (0..3).map(|_| ex.submit(job("complete:n=4"))).collect();
        let busy = tickets
            .iter()
            .filter(|t| matches!(t, Err(SubmitError::Busy { .. })))
            .count();
        assert!(busy >= 1, "expected at least one BUSY rejection");
        if let Err(SubmitError::Busy { retry_after_ms }) =
            tickets.iter().find(|t| t.is_err()).unwrap()
        {
            assert!(*retry_after_ms >= 10, "hint clamped to a floor");
        }
        for t in tickets.into_iter().flatten() {
            t.wait().unwrap();
        }
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn deadline_cancels_job_and_frees_worker() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:200").unwrap()),
            ..quiet_cfg(1, 2)
        };
        let ex = Executor::new(&cfg);
        let t = ex.submit(job("complete:n=4").timeout(0.02)).unwrap();
        let err = t.wait().unwrap_err();
        let c = err.downcast_ref::<Cancelled>().expect("typed Cancelled");
        assert_eq!(c.reason, CancelReason::Deadline);
        // the worker survived and still serves
        let r = ex.submit(job("complete:n=4")).unwrap().wait_decomp().unwrap();
        assert_eq!(r.t_max, 4);
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn explicit_cancel_beats_deadline() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:500").unwrap()),
            ..quiet_cfg(1, 2)
        };
        let ex = Executor::new(&cfg);
        let t = ex.submit(job("complete:n=4")).unwrap();
        t.cancel();
        let err = t.wait().unwrap_err();
        let c = err.downcast_ref::<Cancelled>().expect("typed Cancelled");
        assert_eq!(c.reason, CancelReason::Cancelled);
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn panic_is_isolated_to_the_job() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:panic").unwrap()),
            ..quiet_cfg(1, 2)
        };
        let ex = Executor::new(&cfg);
        let err = ex.submit(job("complete:n=4")).unwrap().wait().unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err:#}");
        // same single worker answers the next request → it survived
        let err2 = ex.submit(job("complete:n=4")).unwrap().wait().unwrap_err();
        assert!(err2.to_string().contains("panicked"), "{err2:#}");
        assert_eq!(ex.inflight(), 0, "RAII guard must release on panic");
        ex.shutdown(Duration::from_secs(5));
    }

    #[test]
    fn shutdown_drains_inflight_job() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:100").unwrap()),
            ..quiet_cfg(1, 2)
        };
        let ex = Executor::new(&cfg);
        let t = ex.submit(job("complete:n=4")).unwrap();
        ex.shutdown(Duration::from_secs(10));
        // drain waited: the reply is a success, not a cancellation
        let r = t.wait_decomp().unwrap();
        assert_eq!(r.t_max, 4);
        assert!(matches!(
            ex.submit(job("complete:n=4")),
            Err(SubmitError::ShuttingDown)
        ));
    }

    #[test]
    fn drain_deadline_cancels_stragglers() {
        let cfg = ExecutorConfig {
            fault: Some(FaultSpec::parse("job.start:10000").unwrap()),
            ..quiet_cfg(1, 2)
        };
        let ex = Executor::new(&cfg);
        let t = ex.submit(job("complete:n=4")).unwrap();
        let t0 = Instant::now();
        ex.shutdown(Duration::from_millis(100));
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown must not wait out a 10s job"
        );
        let err = t.wait().unwrap_err();
        let c = err.downcast_ref::<Cancelled>().expect("typed Cancelled");
        assert_eq!(c.reason, CancelReason::Cancelled);
    }
}
