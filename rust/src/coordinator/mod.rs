//! The coordination layer: job configuration, the decomposition
//! pipeline (load/generate → order → decompose → report), and a
//! multi-client analytics server.
//!
//! This is the "framework" face of the library: examples, the CLI, the
//! benches and the server all drive the same [`pipeline::run_job`].

mod config;
mod pipeline;
mod server;

pub use config::{Algorithm, GraphSpec, JobConfig};
pub use pipeline::{run_job, JobReport};
pub use server::{serve, Client, ServerHandle};
