//! The coordination layer: job configuration, the decomposition
//! pipeline (load/generate → order → decompose → report), a bounded
//! job executor, and a multi-client analytics server.
//!
//! This is the "framework" face of the library: examples, the CLI, the
//! benches and the server all drive the same [`pipeline::run_job`]. The
//! server admits work through [`executor::Executor`] — a fixed worker
//! pool with bounded queueing, per-job deadlines/cancellation, and
//! graceful drain — instead of spawning a thread per request.

mod config;
mod executor;
mod pipeline;
mod server;

pub use config::{Algorithm, GraphSpec, JobConfig};
pub use executor::{
    Executor, ExecutorConfig, FaultAction, FaultSpec, JobFn, JobOutcome, JobTicket, LoadReport,
    SubmitError,
};
pub use pipeline::{run_job, run_job_with, JobReport};
pub use server::{serve, serve_with, Client, ServerConfig, ServerHandle};
