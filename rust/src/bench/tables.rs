//! Tables 1–4 of the paper, regenerated over the synthetic suite.

use crate::gen::{suite, SuiteGraph};
use crate::graph::EdgeGraph;
use crate::kcore;
use crate::metrics::{geomean, gweps, time, Table};
use crate::order::{self, Ordering};
use crate::par::Pool;
use crate::triangle;
use crate::truss;
use crate::util::fmt_secs;

/// Wedge budget above which the WC baseline is skipped (the paper's
/// "did not finish in 1 hour" cells, scaled to this testbed).
const WC_WEDGE_BUDGET: u64 = 2_000_000_000;

/// Table 1: the test-suite statistics — wedges, triangles, m, n, d_max,
/// c_max, t_max, wedge/triangle ratio.
pub fn bench_table1(scale: usize) -> String {
    let mut t = Table::new(&[
        "graph", "family", "|W|(1e6)", "|T|(1e6)", "m(1e3)", "n(1e3)", "dmax", "cmax",
        "tmax", "W/T",
    ]);
    for SuiteGraph { name, family, graph } in suite(scale) {
        let wedges = graph.wedge_count();
        let tri = triangle::count_triangles(&graph);
        let core = kcore::bz(&graph);
        let cmax = kcore::max_coreness(&core);
        let eg = EdgeGraph::new(graph);
        let pool = Pool::with_default_threads();
        let res = truss::pkt(&eg, &pool);
        let tmax = truss::max_trussness(&res.trussness);
        t.row(vec![
            name.into(),
            family.into(),
            format!("{:.3}", wedges as f64 / 1e6),
            format!("{:.3}", tri as f64 / 1e6),
            format!("{:.1}", eg.m() as f64 / 1e3),
            format!("{:.1}", eg.n() as f64 / 1e3),
            format!("{}", eg.g.max_degree()),
            format!("{cmax}"),
            format!("{tmax}"),
            format!("{:.2}", wedges as f64 / tri.max(1) as f64),
        ]);
    }
    format!("## Table 1: graph suite statistics (ordered by wedge count)\n\n{}", t.render())
}

/// Table 2: impact of vertex ordering on (parallel) triangle counting —
/// KCO vs natural time, speedup, the Σd⁺(v)² work estimates under both
/// orders, the work ratio, Σd(v)², and the k-core + reordering times.
pub fn bench_table2(scale: usize, threads: usize) -> String {
    let pool = Pool::new(threads);
    let mut t = Table::new(&[
        "graph", "tri-KCO(s)", "tri-NAT(s)", "speedup", "Sd+2 KCO(1e6)", "Sd+2 NAT(1e6)",
        "work-ratio", "Sd2(1e6)", "Sd2/Sd+2", "kcore(s)", "order(s)",
    ]);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        // the suite generators emit graphs in generator-given (natural)
        // vertex order
        let (kcore_res, kcore_secs) = time(|| kcore::park(&graph, &pool));
        let _ = kcore_res;
        let (ordered, order_secs) = time(|| order::reorder(&graph, Ordering::KCore).0);

        let (_, nat_secs) = time(|| triangle::count_triangles_par(&graph, &pool));
        let (_, kco_secs) = time(|| triangle::count_triangles_par(&ordered, &pool));

        let work_nat = graph.sum_deg_plus_sq();
        let work_kco = ordered.sum_deg_plus_sq();
        let sd2 = graph.sum_deg_sq();
        t.row(vec![
            name.into(),
            fmt_secs(kco_secs),
            fmt_secs(nat_secs),
            format!("{:.2}", nat_secs / kco_secs.max(1e-12)),
            format!("{:.2}", work_kco as f64 / 1e6),
            format!("{:.2}", work_nat as f64 / 1e6),
            format!("{:.2}", work_nat as f64 / work_kco.max(1) as f64),
            format!("{:.2}", sd2 as f64 / 1e6),
            format!("{:.2}", sd2 as f64 / work_kco.max(1) as f64),
            fmt_secs(kcore_secs),
            fmt_secs(order_secs),
        ]);
    }
    format!(
        "## Table 2: vertex ordering impact on triangle counting ({} threads)\n\n{}",
        threads,
        t.render()
    )
}

/// Table 3: sequential decomposition — PKT vs WC vs Ros single-thread
/// times, PKT GWeps, and speedup over Ros.
pub fn bench_table3(scale: usize) -> String {
    let pool1 = Pool::new(1);
    let mut t = Table::new(&[
        "graph", "PKT(s)", "WC(s)", "Ros(s)", "PKT GWeps", "speedup/Ros",
    ]);
    let mut rates = vec![];
    let mut speedups = vec![];
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let wedges = g.wedge_count();
        let eg = EdgeGraph::new(g);
        // PKT time comes from its own obs spans (support + peel), so the
        // table agrees with the registry histograms and any --trace capture
        let pkt_secs = truss::pkt(&eg, &pool1).stats.total_secs;
        let wc_cell = if wedges <= WC_WEDGE_BUDGET {
            let (_, wc_secs) = time(|| truss::wc(&eg));
            fmt_secs(wc_secs)
        } else {
            "-".into()
        };
        let (_, ros_secs) = time(|| truss::ros(&eg, &pool1));
        let rate = gweps(wedges, pkt_secs);
        rates.push(rate);
        speedups.push(ros_secs / pkt_secs.max(1e-12));
        t.row(vec![
            name.into(),
            fmt_secs(pkt_secs),
            wc_cell,
            fmt_secs(ros_secs),
            format!("{rate:.4}"),
            format!("{:.2}", ros_secs / pkt_secs.max(1e-12)),
        ]);
    }
    format!(
        "## Table 3: sequential decomposition (1 thread)\n\n{}\ngeomean PKT rate = {:.4} GWeps, geomean speedup over Ros = {:.2}x\n",
        t.render(),
        geomean(&rates),
        geomean(&speedups)
    )
}

/// Table 4: parallel PKT — T-thread time, GWeps, relative speedup over
/// 1-thread PKT, speedup over (parallel-support) Ros.
pub fn bench_table4(scale: usize, threads: usize) -> String {
    let pool1 = Pool::new(1);
    let pool_t = Pool::new(threads);
    let mut t = Table::new(&[
        "graph", "time(s)", "GWeps", &format!("rel-speedup({threads}t)"), "speedup/Ros",
    ]);
    let mut rates = vec![];
    let mut rels = vec![];
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let wedges = g.wedge_count();
        let eg = EdgeGraph::new(g);
        // span-derived timings (see bench_table3)
        let par_secs = truss::pkt(&eg, &pool_t).stats.total_secs;
        let seq_secs = truss::pkt(&eg, &pool1).stats.total_secs;
        let (_, ros_secs) = time(|| truss::ros(&eg, &pool_t));
        let rate = gweps(wedges, par_secs);
        rates.push(rate);
        rels.push(seq_secs / par_secs.max(1e-12));
        t.row(vec![
            name.into(),
            fmt_secs(par_secs),
            format!("{rate:.4}"),
            format!("{:.2}", seq_secs / par_secs.max(1e-12)),
            format!("{:.2}", ros_secs / par_secs.max(1e-12)),
        ]);
    }
    format!(
        "## Table 4: parallel PKT ({threads} threads)\n\n{}\ngeomean rate = {:.4} GWeps, geomean relative speedup = {:.2}x\n",
        t.render(),
        geomean(&rates),
        geomean(&rels)
    )
}

#[cfg(test)]
mod tests {
    // Bench smoke tests use tiny custom graphs rather than the full
    // suite to keep `cargo test` fast; full-suite runs happen in
    // `cargo bench` / `trussx bench`.
    use super::*;

    #[test]
    fn wc_budget_gate() {
        assert!(WC_WEDGE_BUDGET > 1_000_000);
    }

    #[test]
    fn table_headers_render() {
        // ensure the Table arity in each bench matches by constructing
        // one row through the real code path on a minimal suite scale.
        // (Full execution is covered by `cargo bench`.)
        let mut t = Table::new(&["graph", "x"]);
        t.row(vec!["k".into(), "1".into()]);
        assert!(t.render().contains("graph"));
    }
}
