//! PKT peel-optimization ablation: packed bitset flags and active-graph
//! compaction, on an RMAT graph deep enough (k_max ≥ 20) that the peel
//! runs many levels and the live set shrinks early.
//!
//! Besides the rendered table, the full bench writes a machine-readable
//! `BENCH_pkt.json` (path overridable via `TRUSSX_BENCH_OUT`) so CI and
//! EXPERIMENTS.md can track the ablation without parsing tables.

use crate::gen;
use crate::graph::EdgeGraph;
use crate::metrics::{time, Table};
use crate::order::{self, Ordering};
use crate::par::Pool;
use crate::truss::{self, PktConfig, TrussResult};
use crate::util::fmt_secs;
use anyhow::{bail, Result};

struct Variant {
    name: &'static str,
    cfg: PktConfig,
}

const VARIANTS: [Variant; 4] = [
    Variant { name: "baseline", cfg: PktConfig { compact_threshold: 0.0, use_bitsets: false } },
    Variant { name: "bitset", cfg: PktConfig { compact_threshold: 0.0, use_bitsets: true } },
    Variant { name: "compact", cfg: PktConfig { compact_threshold: 0.3, use_bitsets: false } },
    Variant {
        name: "compact+bitset",
        cfg: PktConfig { compact_threshold: 0.3, use_bitsets: true },
    },
];

/// The `pkt` bench id: run every variant on one deep RMAT graph, check
/// they agree edge-for-edge, render the comparison, and emit the JSON
/// record.
pub fn bench_pkt(scale: usize, threads: usize) -> Result<String> {
    // seed 7 at scale 1: m ≈ 23.5k, k_max = 50 — a long peel with a
    // shrinking live set, the regime compaction targets
    let g0 = gen::rmat(1024, 32_768 * scale.max(1), 0.57, 0.19, 0.19, 7);
    let (g, _) = order::reorder(&g0, Ordering::KCore);
    drop(g0);
    let eg = EdgeGraph::new(g);
    let pool = Pool::new(threads);

    let mut results: Vec<(&'static str, PktConfig, TrussResult)> = Vec::new();
    for v in VARIANTS {
        let (res, _) = time(|| truss::pkt_config(&eg, &pool, &v.cfg));
        results.push((v.name, v.cfg, res));
    }
    for (name, _, res) in &results[1..] {
        if res.trussness != results[0].2.trussness {
            bail!("variant '{name}' disagrees with baseline trussness");
        }
    }
    let kmax = truss::max_trussness(&results[0].2.trussness);
    if kmax < 20 {
        bail!("bench graph too shallow (k_max = {kmax} < 20); adjust the generator");
    }

    let mut t = Table::new(&[
        "variant",
        "support(s)",
        "scan(s)",
        "process(s)",
        "total(s)",
        "levels",
        "rebuilds",
        "compact(s)",
        "scanned-edges",
    ]);
    for (name, _, res) in &results {
        let s = &res.stats;
        t.row(vec![
            (*name).into(),
            fmt_secs(s.support_secs),
            fmt_secs(s.scan_secs),
            fmt_secs(s.process_secs),
            fmt_secs(s.total_secs),
            format!("{}", s.levels),
            format!("{}", s.rebuilds),
            fmt_secs(s.compact_secs),
            format!("{}", s.scanned_edges),
        ]);
    }

    let json = render_json(&eg, kmax, threads, &results);
    let out_path = std::env::var("TRUSSX_BENCH_OUT").unwrap_or_else(|_| "BENCH_pkt.json".into());
    std::fs::write(&out_path, &json)?;

    Ok(format!(
        "## PKT peel optimizations: compaction + bitset ablation ({threads} threads)\n\n\
         graph: rmat(n=1024, m={}, seed=7), k_max={kmax}\n\n{}\nwrote {out_path}\n",
        eg.m(),
        t.render()
    ))
}

/// Hand-rolled JSON (the offline registry carries no serde).
fn render_json(
    eg: &EdgeGraph,
    kmax: u32,
    threads: usize,
    results: &[(&'static str, PktConfig, TrussResult)],
) -> String {
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"pkt\",\n");
    j.push_str("  \"graph\": \"rmat:n=1024,seed=7\",\n");
    j.push_str(&format!("  \"n\": {},\n", eg.n()));
    j.push_str(&format!("  \"m\": {},\n", eg.m()));
    j.push_str(&format!("  \"kmax\": {kmax},\n"));
    j.push_str(&format!("  \"threads\": {threads},\n"));
    j.push_str("  \"variants\": [\n");
    for (i, (name, cfg, res)) in results.iter().enumerate() {
        let s = &res.stats;
        j.push_str("    {\n");
        j.push_str(&format!("      \"name\": \"{name}\",\n"));
        j.push_str(&format!(
            "      \"compact_threshold\": {},\n",
            cfg.compact_threshold
        ));
        j.push_str(&format!("      \"use_bitsets\": {},\n", cfg.use_bitsets));
        j.push_str(&format!("      \"support_secs\": {:.6},\n", s.support_secs));
        j.push_str(&format!("      \"scan_secs\": {:.6},\n", s.scan_secs));
        j.push_str(&format!("      \"process_secs\": {:.6},\n", s.process_secs));
        j.push_str(&format!("      \"total_secs\": {:.6},\n", s.total_secs));
        j.push_str(&format!("      \"levels\": {},\n", s.levels));
        j.push_str(&format!("      \"rebuilds\": {},\n", s.rebuilds));
        j.push_str(&format!("      \"compact_secs\": {:.6},\n", s.compact_secs));
        j.push_str(&format!("      \"scanned_edges\": {}\n", s.scanned_edges));
        j.push_str(if i + 1 < results.len() { "    },\n" } else { "    }\n" });
    }
    j.push_str("  ]\n}\n");
    j
}

/// Release-mode CI smoke check (`pallas bench --smoke`): a small deep
/// RMAT graph, every config variant checked against the serial WC
/// oracle. Any disagreement or panic fails the run; no files written.
pub fn smoke(threads: usize) -> Result<String> {
    let g0 = gen::rmat(256, 8192, 0.57, 0.19, 0.19, 7);
    let (g, _) = order::reorder(&g0, Ordering::KCore);
    drop(g0);
    let eg = EdgeGraph::new(g);
    let oracle = truss::wc(&eg);
    let kmax = truss::max_trussness(&oracle.trussness);
    let pool = Pool::new(threads);
    for v in VARIANTS {
        let res = truss::pkt_config(&eg, &pool, &v.cfg);
        if res.trussness != oracle.trussness {
            bail!("smoke: pkt variant '{}' disagrees with the WC oracle", v.name);
        }
    }
    Ok(format!(
        "smoke OK: rmat(n=256, m={}) k_max={kmax}, {} pkt variants agree with wc ({threads} threads)",
        eg.m(),
        VARIANTS.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_passes() {
        let out = smoke(2).unwrap();
        assert!(out.contains("smoke OK"), "{out}");
    }

    #[test]
    fn json_shape() {
        // tiny stand-in run so the test stays fast: reuse render_json on
        // real results from a small graph
        let eg = EdgeGraph::new(gen::planted_partition(2, 10, 0.9, 0.05, 3));
        let pool = Pool::new(2);
        let results: Vec<(&'static str, PktConfig, TrussResult)> = VARIANTS
            .iter()
            .map(|v| (v.name, v.cfg, truss::pkt_config(&eg, &pool, &v.cfg)))
            .collect();
        let j = render_json(&eg, 5, 2, &results);
        assert!(j.contains("\"bench\": \"pkt\""));
        assert!(j.contains("\"compact+bitset\""));
        assert!(j.contains("\"scanned_edges\""));
        assert_eq!(j.matches("\"name\"").count(), 4);
    }
}
