//! Figures 4–6 of the paper, regenerated as text series.

use crate::gen::{suite, suite_by_name, SuiteGraph};
use crate::graph::EdgeGraph;
use crate::metrics::Table;
use crate::order::{self, Ordering};
use crate::par::Pool;
use crate::truss;
use crate::util::fmt_secs;

/// Figure 4: fraction of PKT time per stage (support / scan / process).
pub fn bench_fig4(scale: usize, threads: usize) -> String {
    let pool = Pool::new(threads);
    let mut t = Table::new(&["graph", "support%", "scan%", "process%", "other%", "total(s)"]);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let res = truss::pkt(&eg, &pool);
        let s = &res.stats;
        let total = s.total_secs.max(1e-12);
        let other = (total - s.support_secs - s.scan_secs - s.process_secs).max(0.0);
        t.row(vec![
            name.into(),
            format!("{:.1}", 100.0 * s.support_secs / total),
            format!("{:.1}", 100.0 * s.scan_secs / total),
            format!("{:.1}", 100.0 * s.process_secs / total),
            format!("{:.1}", 100.0 * other / total),
            fmt_secs(total),
        ]);
    }
    format!(
        "## Figure 4: PKT execution-time breakdown by stage ({threads} threads)\n\n{}",
        t.render()
    )
}

/// Figure 5: PKT relative scaling — time and speedup at 1..=max threads
/// (powers of two).
pub fn bench_fig5(scale: usize, max_threads: usize) -> String {
    let mut counts = vec![1usize];
    while counts.last().unwrap() * 2 <= max_threads.max(1) {
        counts.push(counts.last().unwrap() * 2);
    }
    let headers: Vec<String> = std::iter::once("graph".to_string())
        .chain(counts.iter().map(|t| format!("{t}t(s)")))
        .chain(counts.iter().map(|t| format!("su{t}t")))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let times: Vec<f64> = counts
            .iter()
            .map(|&t| {
                let pool = Pool::new(t);
                let start = std::time::Instant::now();
                let _ = truss::pkt(&eg, &pool);
                start.elapsed().as_secs_f64()
            })
            .collect();
        let mut row = vec![name.to_string()];
        row.extend(times.iter().map(|&s| fmt_secs(s)));
        row.extend(times.iter().map(|&s| format!("{:.2}", times[0] / s.max(1e-12))));
        table.row(row);
    }
    format!(
        "## Figure 5: PKT parallel relative scaling (thread counts {counts:?})\n\n{}\nNOTE: this container exposes {} hardware thread(s); speedups beyond that count measure synchronization overhead only (see DESIGN.md §2).\n",
        table.render(),
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    )
}

/// Figure 6: trussness and execution-time distributions for the uk-2002
/// analogue (web-pp-m): CDF of edges by trussness and CDF of processing
/// time by peel level.
pub fn bench_fig6(scale: usize, threads: usize) -> String {
    let sg = suite_by_name("web-pp-m", scale).expect("suite graph");
    let (g, _) = order::reorder(&sg.graph, Ordering::KCore);
    let eg = EdgeGraph::new(g);
    let pool = Pool::new(threads);
    let res = truss::pkt(&eg, &pool);
    let m = eg.m() as f64;

    // CDFs over peel levels (level l ↔ trussness l+2)
    let mut t = Table::new(&["trussness", "edges", "edge-CDF%", "level(s)", "time-CDF%"]);
    let total_time: f64 = res.stats.per_level.iter().map(|l| l.secs).sum();
    let mut edge_cum = 0u64;
    let mut time_cum = 0.0;
    let mut p50_truss = None;
    let mut p90_truss = None;
    let mut p50_time = None;
    let mut p90_time = None;
    for ls in &res.stats.per_level {
        edge_cum += ls.edges;
        time_cum += ls.secs;
        let ecdf = 100.0 * edge_cum as f64 / m;
        let tcdf = 100.0 * time_cum / total_time.max(1e-12);
        let k = ls.level + 2;
        if p50_truss.is_none() && ecdf >= 50.0 {
            p50_truss = Some(k);
        }
        if p90_truss.is_none() && ecdf >= 90.0 {
            p90_truss = Some(k);
        }
        if p50_time.is_none() && tcdf >= 50.0 {
            p50_time = Some(k);
        }
        if p90_time.is_none() && tcdf >= 90.0 {
            p90_time = Some(k);
        }
        t.row(vec![
            format!("{k}"),
            format!("{}", ls.edges),
            format!("{ecdf:.1}"),
            format!("{:.5}", ls.secs),
            format!("{tcdf:.1}"),
        ]);
    }
    format!(
        "## Figure 6: trussness & time distributions for {} ({} threads)\n\n{}\n50% of edges at trussness <= {:?}, 90% at <= {:?}; 50% of time at trussness <= {:?}, 90% at <= {:?} (t_max = {}).\n",
        sg.name,
        threads,
        t.render(),
        p50_truss.unwrap_or(0),
        p90_truss.unwrap_or(0),
        p50_time.unwrap_or(0),
        p90_time.unwrap_or(0),
        truss::max_trussness(&res.trussness)
    )
}
