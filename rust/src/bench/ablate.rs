//! Extension benches: design-choice ablations and the XLA dense-block
//! backend comparison (DESIGN.md §3, rows `ablate` and `xla`).

use crate::gen::{suite, SuiteGraph};
#[cfg(feature = "xla")]
use crate::gen::suite_by_name;
use crate::graph::EdgeGraph;
use crate::metrics::{time, Table};
use crate::order::{self, Ordering};
use crate::par::Pool;
use crate::triangle;
use crate::truss;
use crate::util::fmt_secs;
#[cfg(feature = "xla")]
use anyhow::Result;
use crate::par::sync::atomic::AtomicI32;

/// Ablations of PKT design choices called out in DESIGN.md:
/// (a) support computation method inside the peel (oriented AM4 vs
///     unoriented Ros);
/// (b) vertex ordering fed to the whole pipeline (NAT vs DEG vs KCO).
pub fn bench_ablate(scale: usize, threads: usize) -> String {
    let pool = Pool::new(threads);
    let mut out = String::new();

    // (a) support method ablation
    let mut t = Table::new(&["graph", "AM4-support(s)", "Ros-support(s)", "ratio"]);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let (_, am4_secs) = time(|| triangle::support_am4(&eg, &pool));
        let (_, ros_secs) = time(|| triangle::support_ros(&eg, &pool));
        t.row(vec![
            name.into(),
            fmt_secs(am4_secs),
            fmt_secs(ros_secs),
            format!("{:.2}", ros_secs / am4_secs.max(1e-12)),
        ]);
    }
    out.push_str(&format!(
        "## Ablation (a): support computation method ({threads} threads)\n\n{}\n",
        t.render()
    ));

    // (b) ordering ablation over full PKT
    let mut t = Table::new(&["graph", "PKT-NAT(s)", "PKT-DEG(s)", "PKT-KCO(s)", "NAT/KCO"]);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let mut secs = vec![];
        for ord in [Ordering::Natural, Ordering::Degree, Ordering::KCore] {
            let (g, _) = order::reorder(&graph, ord);
            let eg = EdgeGraph::new(g);
            let (_, s) = time(|| truss::pkt(&eg, &pool));
            secs.push(s);
        }
        t.row(vec![
            name.into(),
            fmt_secs(secs[0]),
            fmt_secs(secs[1]),
            fmt_secs(secs[2]),
            format!("{:.2}", secs[0] / secs[2].max(1e-12)),
        ]);
    }
    out.push_str(&format!(
        "## Ablation (b): vertex ordering fed to PKT ({threads} threads)\n\n{}\n",
        t.render()
    ));

    // (c) peel with precomputed support: isolates the peel phase cost
    let mut t = Table::new(&["graph", "peel-only(s)", "support-only(s)", "peel/support"]);
    for SuiteGraph { name, graph, .. } in suite(scale) {
        let (g, _) = order::reorder(&graph, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let (s0, support_secs) = time(|| triangle::support_am4(&eg, &pool));
        let s: Vec<AtomicI32> =
            s0.into_iter().map(|a| AtomicI32::new(a.into_inner() as i32)).collect();
        let (_, peel_secs) = time(|| truss::pkt_with_support(&eg, &pool, s));
        t.row(vec![
            name.into(),
            fmt_secs(peel_secs),
            fmt_secs(support_secs),
            format!("{:.2}", peel_secs / support_secs.max(1e-12)),
        ]);
    }
    out.push_str(&format!(
        "## Ablation (c): peel vs support phase cost ({threads} threads)\n\n{}",
        t.render()
    ));
    out
}

/// XLA dense-block backend: agreement + time vs native PKT on graphs
/// that fit one dense block, across the available block sizes.
/// Only built with the `xla` feature (requires the PJRT runtime).
#[cfg(feature = "xla")]
pub fn bench_xla() -> Result<String> {
    let dir = crate::runtime::artifacts_dir();
    let mut rt = crate::runtime::Runtime::cpu()?;
    let manifest = match rt.load_manifest(&dir) {
        Ok(m) => m,
        Err(e) => {
            return Ok(format!(
                "## XLA dense-block bench: SKIPPED (artifacts not found at {}: {e:#})\nRun `make artifacts` first.\n",
                dir.display()
            ))
        }
    };
    let mut t = Table::new(&["graph", "n", "block", "xla-decomp(s)", "pkt(s)", "agree"]);
    let cases = [
        ("pp-2x24", crate::gen::planted_partition(2, 24, 0.8, 0.02, 7)),
        ("pp-4x20", crate::gen::planted_partition(4, 20, 0.7, 0.01, 8)),
        ("er-100", crate::gen::erdos_renyi(100, 0.12, 9)),
        ("k32", crate::gen::complete(32)),
        ("ba-120", crate::gen::barabasi_albert(120, 5, 10)),
    ];
    let pool = Pool::with_default_threads();
    for (name, g) in cases {
        let eg = EdgeGraph::new(g);
        let backend = truss::dense::DenseBackend::for_graph(&rt, &manifest, eg.n())?;
        let (xla_truss, xla_secs) = time(|| backend.decompose(&eg));
        let xla_truss = xla_truss?;
        let (res, pkt_secs) = time(|| truss::pkt(&eg, &pool));
        t.row(vec![
            name.into(),
            format!("{}", eg.n()),
            format!("{}", backend.block),
            fmt_secs(xla_secs),
            fmt_secs(pkt_secs),
            format!("{}", xla_truss == res.trussness),
        ]);
    }
    // block-size sweep on one graph
    let mut sweep = Table::new(&["block", "support(s)", "decomp(s)"]);
    let g = suite_by_name("web-pp-s", 1).unwrap().graph;
    let small = {
        // shrink to the largest block size available
        let bmax = *manifest.support_blocks().last().unwrap_or(&0);
        let keep: Vec<(u32, u32)> = (0..g.n() as u32)
            .flat_map(|u| {
                g.neighbors(u)
                    .iter()
                    .filter(move |&&v| v > u && (v as usize) < bmax && (u as usize) < bmax)
                    .map(move |&v| (u, v))
            })
            .collect();
        crate::graph::GraphBuilder::new().edges_vec(keep).build()
    };
    let eg = EdgeGraph::new(small);
    for b in manifest.support_blocks() {
        if b < eg.n() {
            continue;
        }
        let backend = truss::dense::DenseBackend::with_block(&rt, b);
        let (_, s_secs) = time(|| backend.support(&eg).unwrap());
        let (_, d_secs) = time(|| backend.decompose(&eg).unwrap());
        sweep.row(vec![format!("{b}"), fmt_secs(s_secs), fmt_secs(d_secs)]);
    }
    Ok(format!(
        "## XLA dense-block backend vs native PKT\n\n{}\n### Block-size sweep (subgraph n={})\n\n{}",
        t.render(),
        eg.n(),
        sweep.render()
    ))
}
