//! Bench harness regenerating every table and figure of the paper.
//! See DESIGN.md §3 for the experiment index.

mod tables;
mod figures;
mod ablate;
mod pkt;

pub use ablate::bench_ablate;
#[cfg(feature = "xla")]
pub use ablate::bench_xla;
pub use figures::{bench_fig4, bench_fig5, bench_fig6};
pub use pkt::{bench_pkt, smoke};
pub use tables::{bench_table1, bench_table2, bench_table3, bench_table4};

use anyhow::{bail, Result};

/// Run a bench by experiment id, writing its report to the returned
/// string (also printed by the CLI/bench shims).
pub fn run(id: &str, scale: usize, threads: usize) -> Result<String> {
    match id {
        "table1" => Ok(bench_table1(scale)),
        "table2" => Ok(bench_table2(scale, threads)),
        "table3" => Ok(bench_table3(scale)),
        "table4" => Ok(bench_table4(scale, threads)),
        "fig4" => Ok(bench_fig4(scale, threads)),
        "fig5" => Ok(bench_fig5(scale, threads)),
        "fig6" => Ok(bench_fig6(scale, threads)),
        "ablate" => Ok(bench_ablate(scale, threads)),
        "pkt" => bench_pkt(scale, threads),
        #[cfg(feature = "xla")]
        "xla" => bench_xla(),
        #[cfg(not(feature = "xla"))]
        "xla" => bail!("bench 'xla' requires a build with `--features xla`"),
        _ => bail!("unknown bench id '{id}' (table1-4, fig4-6, ablate, pkt, xla)"),
    }
}

/// All experiment ids in run order (`xla` only when that feature is on).
#[cfg(feature = "xla")]
pub const ALL: [&str; 10] = [
    "table1", "table2", "table3", "table4", "fig4", "fig5", "fig6", "ablate", "pkt", "xla",
];
#[cfg(not(feature = "xla"))]
pub const ALL: [&str; 9] =
    ["table1", "table2", "table3", "table4", "fig4", "fig5", "fig6", "ablate", "pkt"];
