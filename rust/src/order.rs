//! Vertex orderings and graph relabeling.
//!
//! The paper preprocesses every graph by k-core-ordering its vertices
//! (Table 2 shows up to 17× triangle-counting speedup from this). An
//! ordering here is a permutation `perm` where `perm[old] = new`.

use crate::graph::{Graph, GraphBuilder, Vertex};
use crate::kcore;

/// Which vertex ordering to apply before decomposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Leave vertex ids as-is (the paper's NAT).
    Natural,
    /// Ascending degree.
    Degree,
    /// Ascending coreness, ties by degree (the paper's KCO).
    KCore,
}

impl Ordering {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "natural" | "nat" => Some(Self::Natural),
            "degree" | "deg" => Some(Self::Degree),
            "kcore" | "kco" => Some(Self::KCore),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Natural => "natural",
            Self::Degree => "degree",
            Self::KCore => "kcore",
        }
    }
}

/// Compute the permutation (`perm[old] = new`) for an ordering.
pub fn permutation(g: &Graph, ord: Ordering) -> Vec<Vertex> {
    let n = g.n();
    match ord {
        Ordering::Natural => (0..n as Vertex).collect(),
        Ordering::Degree => {
            let key: Vec<u64> = (0..n).map(|u| g.degree(u as Vertex) as u64).collect();
            perm_from_key(&key)
        }
        Ordering::KCore => {
            let core = kcore::bz(g);
            // coreness major, degree minor — matches the paper's
            // "increasing order of coreness" with a stabilizing tiebreak
            let key: Vec<u64> = (0..n)
                .map(|u| ((core[u] as u64) << 32) | g.degree(u as Vertex) as u64)
                .collect();
            perm_from_key(&key)
        }
    }
}

/// Stable counting-sort-free permutation from sort keys:
/// `perm[old] = rank of old when sorted by (key, old)`.
fn perm_from_key(key: &[u64]) -> Vec<Vertex> {
    let n = key.len();
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_by_key(|&u| (key[u as usize], u));
    let mut perm = vec![0 as Vertex; n];
    for (new, &old) in idx.iter().enumerate() {
        perm[old as usize] = new as Vertex;
    }
    perm
}

/// Apply a permutation (`perm[old] = new`), producing the relabeled graph.
pub fn relabel(g: &Graph, perm: &[Vertex]) -> Graph {
    assert_eq!(perm.len(), g.n());
    let mut edges = Vec::with_capacity(g.m());
    for u in 0..g.n() as Vertex {
        for &v in g.neighbors(u) {
            if v > u {
                edges.push((perm[u as usize], perm[v as usize]));
            }
        }
    }
    GraphBuilder::new().num_vertices(g.n()).edges_vec(edges).build()
}

/// Convenience: relabel `g` by `ord`, returning (graph, permutation).
pub fn reorder(g: &Graph, ord: Ordering) -> (Graph, Vec<Vertex>) {
    let perm = permutation(g, ord);
    match ord {
        Ordering::Natural => (g.clone(), perm),
        _ => (relabel(g, &perm), perm),
    }
}

/// Check that `perm` is a permutation of 0..n (test/debug helper).
pub fn is_permutation(perm: &[Vertex]) -> bool {
    let n = perm.len();
    let mut seen = vec![false; n];
    for &p in perm {
        if p as usize >= n || seen[p as usize] {
            return false;
        }
        seen[p as usize] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::forall;

    #[test]
    fn natural_is_identity() {
        let g = gen::complete(5);
        let (g2, perm) = reorder(&g, Ordering::Natural);
        assert_eq!(g, g2);
        assert_eq!(perm, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn degree_order_sorts_degrees() {
        let g = gen::star(6); // vertex 0 is the hub
        let perm = permutation(&g, Ordering::Degree);
        // hub must get the highest new id
        assert_eq!(perm[0], 5);
    }

    #[test]
    fn kcore_order_puts_low_core_first() {
        // K5 with a pendant vertex 5 attached to 0
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((0, 5));
        let g = crate::graph::GraphBuilder::new().edges_vec(edges).build();
        let perm = permutation(&g, Ordering::KCore);
        // pendant (coreness 1) must come before all K5 vertices (coreness 4)
        assert_eq!(perm[5], 0);
    }

    #[test]
    fn relabel_preserves_structure() {
        forall("relabel-structure", 24, |rng| {
            let n = rng.range(2, 40);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            for ord in [Ordering::Degree, Ordering::KCore] {
                let (g2, perm) = reorder(&g, ord);
                assert!(is_permutation(&perm));
                assert_eq!(g.n(), g2.n());
                assert_eq!(g.m(), g2.m());
                // spot-check edge preservation
                for u in 0..g.n() as Vertex {
                    for &v in g.neighbors(u) {
                        assert!(g2.has_edge(perm[u as usize], perm[v as usize]));
                    }
                }
                // degree multiset preserved
                let mut d1: Vec<_> = (0..n).map(|u| g.degree(u as u32)).collect();
                let mut d2: Vec<_> = (0..n).map(|u| g2.degree(u as u32)).collect();
                d1.sort_unstable();
                d2.sort_unstable();
                assert_eq!(d1, d2);
            }
        });
    }

    #[test]
    fn kcore_ordering_reduces_work_on_skewed_graph() {
        // The whole point of KCO (Table 2): Σd⁺(v)² drops vs natural.
        let g = gen::rmat(4096, 20_000, 0.65, 0.15, 0.15, 77);
        let (gk, _) = reorder(&g, Ordering::KCore);
        let nat = g.sum_deg_plus_sq();
        let kco = gk.sum_deg_plus_sq();
        assert!(kco < nat, "KCO {kco} should beat NAT {nat}");
    }

    #[test]
    fn is_permutation_detects_bad() {
        assert!(is_permutation(&[2, 0, 1]));
        assert!(!is_permutation(&[0, 0, 1]));
        assert!(!is_permutation(&[0, 3, 1]));
    }
}
