//! pallas CLI — the leader entrypoint.
//!
//! ```text
//! pallas decompose <graphspec> [--algo pkt|wc|ros|local] [--threads N]
//!                  [--order nat|deg|kco] [--hist] [--validate]
//!                  [--compact-threshold F] [--no-bitsets] [--job-timeout SECS]
//! pallas update <graphspec> [--insert u-v[,u-v..]] [--remove u-v[,u-v..]]
//!               [--threads N] [--validate] [--bench]
//! pallas stats <graphspec>
//! pallas bench <id|all> [--scale S] [--threads N] [--smoke]
//! pallas serve [--addr HOST:PORT] [--workers N] [--queue-depth N]
//!              [--job-timeout SECS] [--drain-secs SECS]
//! pallas generate <graphspec> --out FILE[.el|.bin]
//! pallas report <trace.jsonl>
//! pallas lint [root...]
//! ```
//!
//! The global `--trace <path>` flag (any position) streams one JSONL
//! event per closed phase span to `path`; `pallas report` renders the
//! phase/level tables back from such a capture.
//!
//! (Arg parsing is hand-rolled: the offline registry carries no clap.)

use anyhow::{anyhow, bail, Context, Result};
use trussx::coordinator::{
    run_job_with, serve_with, Algorithm, ExecutorConfig, GraphSpec, JobConfig, ServerConfig,
};
use trussx::par::CancelToken;
use trussx::graph::{io, EdgeGraph};
use trussx::kcore;
use trussx::obs;
use trussx::order::Ordering;
use trussx::par::Pool;
use trussx::triangle;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&mut args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &mut Vec<String>) -> Result<()> {
    // global --trace flag: extract before command dispatch
    if let Some(i) = args.iter().position(|a| a == "--trace") {
        anyhow::ensure!(i + 1 < args.len(), "--trace needs a file path");
        let path = args.remove(i + 1);
        args.remove(i);
        obs::sink::set_path(&path).with_context(|| format!("opening trace file {path}"))?;
    }
    let result = dispatch(args);
    obs::sink::flush();
    result
}

/// Minimal option scanner: collects `--key value` pairs and positionals.
struct Opts {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Opts {
    fn parse(args: &[String], known_switches: &[&str]) -> Result<Self> {
        let mut positional = vec![];
        let mut flags = vec![];
        let mut switches = vec![];
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if known_switches.contains(&key) {
                    switches.push(key.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("--{key} needs a value"))?;
                    flags.push((key.to_string(), v.clone()));
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Self { positional, flags, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "decompose" => cmd_decompose(rest),
        "update" => cmd_update(rest),
        "query" => cmd_query(rest),
        "stats" => cmd_stats(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "generate" => cmd_generate(rest),
        "report" => cmd_report(rest),
        "lint" => cmd_lint(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `pallas help`)"),
    }
}

fn print_help() {
    println!(
        "pallas — shared-memory graph truss decomposition (PKT)\n\n\
         USAGE:\n  pallas decompose <graphspec> [--algo pkt|wc|ros|local] [--threads N] [--order nat|deg|kco] [--hist]\n                   [--compact-threshold F] [--no-bitsets]   (pkt peel tuning)\n                   [--validate]   (deep invariant checks; also via TRUSSX_VALIDATE=1)\n                   [--job-timeout SECS]   (deadline; stops at the next level boundary)\n  \
         pallas update <graphspec> [--insert u-v[,u-v..]] [--remove u-v[,u-v..]] [--threads N]\n                   [--validate]   (differential check after every batch)\n                   [--bench]      (update cost vs full recompute, batch sizes 1/8/256)\n  \
         pallas stats <graphspec>\n  \
         pallas bench <table1|table2|table3|table4|fig4|fig5|fig6|ablate|pkt|xla|all> [--scale S] [--threads N] [--smoke]\n  \
         pallas query <graphspec> --vertex V [--k K]\n  \
         pallas serve [--addr HOST:PORT] [--workers N] [--queue-depth N] [--job-timeout SECS] [--drain-secs SECS]\n  \
         pallas generate <graphspec> --out FILE(.el|.bin)\n  \
         pallas report <trace.jsonl>\n  \
         pallas lint [root...]   (concurrency-hygiene source lint; default roots rust/src)\n\n\
         GLOBAL FLAGS:\n  --trace FILE   stream phase-span events (JSONL) to FILE\n\n\
         GRAPH SPECS:\n  suite:<name>  rmat:n=..,m=..  er:n=..,p=..  ba:n=..,k=..\n  \
         ws:n=..,k=..,beta=..  pp:blocks=..,size=..,pin=..,pout=..\n  complete:n=..  file:/path\n"
    );
}

fn cmd_report(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let path = o
        .positional
        .first()
        .context("missing trace file (usage: pallas report <trace.jsonl>)")?;
    print!("{}", obs::report::render_trace_report(path)?);
    Ok(())
}

fn cmd_decompose(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["hist", "no-bitsets", "validate"])?;
    let spec_str = o.positional.first().context("missing graph spec")?;
    let mut cfg = JobConfig::new(GraphSpec::parse(spec_str)?);
    if let Some(a) = o.get("algo") {
        cfg.algorithm = Algorithm::parse(a)?;
    }
    if let Some(t) = o.get("threads") {
        cfg.threads = t.parse().context("bad --threads")?;
    }
    if let Some(ord) = o.get("order") {
        cfg.ordering = Ordering::parse(ord).ok_or_else(|| anyhow!("bad --order '{ord}'"))?;
    }
    if let Some(thr) = o.get("compact-threshold") {
        cfg.pkt.compact_threshold = thr.parse().context("bad --compact-threshold")?;
    }
    if o.has("no-bitsets") {
        cfg.pkt.use_bitsets = false;
    }
    cfg.validate = o.has("validate");
    if let Some(t) = o.get("job-timeout") {
        let secs: f64 = t.parse().context("bad --job-timeout")?;
        anyhow::ensure!(
            secs.is_finite() && secs >= 0.0,
            "--job-timeout wants seconds >= 0"
        );
        cfg.timeout = Some(secs);
    }
    // arm the deadline directly: outside the server there is no
    // executor to do it for us
    let token =
        CancelToken::with_timeout(cfg.timeout.map(std::time::Duration::from_secs_f64));
    let report = run_job_with(&cfg, &token)?;
    println!("{}", report.summary());
    if cfg.validate || trussx::validate::env_enabled() {
        println!("validation: all checks passed ({:.4}s)", report.validate_secs);
    }
    println!(
        "phases: support={:.4}s scan={:.4}s process={:.4}s (levels={}, sublevels={})",
        report.stats.support_secs,
        report.stats.scan_secs,
        report.stats.process_secs,
        report.stats.levels,
        report.stats.sublevels
    );
    if report.stats.rebuilds > 0 {
        println!(
            "compaction: {} rebuilds, {:.4}s, {} edges scanned",
            report.stats.rebuilds, report.stats.compact_secs, report.stats.scanned_edges
        );
    }
    if o.has("hist") {
        println!("trussness histogram:");
        for (k, &c) in report.histogram.iter().enumerate() {
            if c > 0 {
                println!("  k={k}: {c} edges");
            }
        }
    }
    Ok(())
}

fn cmd_update(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["validate", "bench"])?;
    let spec_str = o.positional.first().context("missing graph spec")?;
    let g = GraphSpec::parse(spec_str)?.build()?;
    let threads: usize = o
        .get("threads")
        .map(|s| s.parse())
        .transpose()
        .context("bad --threads")?
        .unwrap_or_else(Pool::default_threads);
    // scoped, so the differential oracle runs after every batch below
    let _validate_guard = o.has("validate").then(trussx::validate::enable_scoped);
    let mut dt = trussx::truss::DynamicTruss::new(g, threads);
    println!("loaded: n={} m={} tmax={}", dt.n(), dt.m(), dt.t_max());
    if o.has("bench") {
        return bench_update(&mut dt, threads);
    }
    let mut any = false;
    for (k, v) in &o.flags {
        let rep = match k.as_str() {
            "insert" => dt.insert_batch(&parse_edge_list(v)?),
            "remove" => dt.remove_batch(&parse_edge_list(v)?),
            "threads" => continue,
            other => bail!("unknown flag --{other}"),
        };
        any = true;
        println!("{}", rep.summary());
    }
    anyhow::ensure!(any, "nothing to do (pass --insert/--remove u-v[,u-v...] or --bench)");
    Ok(())
}

/// CLI twin of the server's edge wire format: `u-v[,u-v...]`.
fn parse_edge_list(s: &str) -> Result<Vec<(u32, u32)>> {
    let mut out = Vec::new();
    for pair in s.split(',').filter(|p| !p.is_empty()) {
        let (u, v) = pair
            .split_once('-')
            .with_context(|| format!("bad edge '{pair}' (want u-v)"))?;
        out.push((
            u.parse().with_context(|| format!("bad vertex '{u}' in '{pair}'"))?,
            v.parse().with_context(|| format!("bad vertex '{v}' in '{pair}'"))?,
        ));
    }
    anyhow::ensure!(!out.is_empty(), "empty edge list (want u-v[,u-v...])");
    Ok(out)
}

/// `--bench`: remove then re-insert spread-out existing edges at batch
/// sizes 1/8/256, timing each maintained update against a from-scratch
/// PKT run on the same graph (the EXPERIMENTS.md update-cost table).
fn bench_update(dt: &mut trussx::truss::DynamicTruss, threads: usize) -> Result<()> {
    use std::time::Instant;
    let pool = Pool::new(threads);
    println!("batch  op      update_secs  full_secs    speedup  affected  changed");
    for &bs in &[1usize, 8, 256] {
        let m = dt.m();
        if m < bs {
            println!("{bs:<6} (skipped: graph has only {m} edges)");
            continue;
        }
        // a deterministic spread of existing edges: remove, then re-add
        let batch: Vec<(u32, u32)> = (0..bs).map(|i| dt.eg().el[i * m / bs]).collect();
        for insert in [false, true] {
            let t0 = Instant::now();
            let rep = if insert {
                dt.insert_batch(&batch)
            } else {
                dt.remove_batch(&batch)
            };
            let update_secs = t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let full = trussx::truss::pkt(dt.eg(), &pool);
            let full_secs = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                full.trussness == dt.trussness(),
                "maintained trussness diverged from recompute at batch={bs}"
            );
            println!(
                "{bs:<6} {:<7} {update_secs:<12.6} {full_secs:<12.6} {:<8.1} {:<9} {}",
                rep.op.name(),
                full_secs / update_secs.max(1e-9),
                rep.affected,
                rep.changed,
            );
        }
    }
    Ok(())
}

fn cmd_stats(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let spec_str = o.positional.first().context("missing graph spec")?;
    let g = GraphSpec::parse(spec_str)?.build()?;
    let pool = Pool::with_default_threads();
    let tri = triangle::count_triangles_par(&g, &pool);
    let core = kcore::bz(&g);
    let eg = EdgeGraph::new(g);
    println!("graph    : {spec_str}");
    println!("n        : {}", eg.n());
    println!("m        : {}", eg.m());
    println!("wedges   : {}", eg.g.wedge_count());
    println!("triangles: {tri}");
    println!("dmax     : {}", eg.g.max_degree());
    println!("cmax     : {}", kcore::max_coreness(&core));
    println!(
        "wedge/triangle ratio: {:.2}",
        eg.g.wedge_count() as f64 / tri.max(1) as f64
    );
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["smoke"])?;
    let id = o.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let scale: usize = o.get("scale").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let threads: usize = o
        .get("threads")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(Pool::default_threads);
    if o.has("smoke") {
        // fast release-mode correctness check for CI: errors/panics fail it
        let report = trussx::bench::smoke(threads)?;
        println!("{report}");
        return Ok(());
    }
    let ids: Vec<&str> = if id == "all" {
        trussx::bench::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let report = trussx::bench::run(id, scale, threads)?;
        println!("{report}\n");
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let addr = o.get("addr").unwrap_or("127.0.0.1:7077");
    let mut exec = ExecutorConfig::default();
    if let Some(w) = o.get("workers") {
        exec.workers = w.parse().context("bad --workers")?;
        anyhow::ensure!(exec.workers >= 1, "--workers wants at least 1");
    }
    if let Some(q) = o.get("queue-depth") {
        exec.queue_depth = q.parse().context("bad --queue-depth")?;
        anyhow::ensure!(exec.queue_depth >= 1, "--queue-depth wants at least 1");
    }
    if let Some(t) = o.get("job-timeout") {
        let secs: f64 = t.parse().context("bad --job-timeout")?;
        anyhow::ensure!(
            secs.is_finite() && secs >= 0.0,
            "--job-timeout wants seconds >= 0"
        );
        exec.job_timeout = Some(std::time::Duration::from_secs_f64(secs));
    }
    let mut cfg = ServerConfig { executor: exec, ..ServerConfig::default() };
    if let Some(d) = o.get("drain-secs") {
        let secs: f64 = d.parse().context("bad --drain-secs")?;
        anyhow::ensure!(
            secs.is_finite() && secs >= 0.0,
            "--drain-secs wants seconds >= 0"
        );
        cfg.drain = std::time::Duration::from_secs_f64(secs);
    }
    println!(
        "executor: {} worker(s), queue depth {}, job timeout {}, drain {:?}",
        cfg.executor.workers,
        cfg.executor.queue_depth,
        cfg.executor
            .job_timeout
            .map_or("off".to_string(), |t| format!("{t:?}")),
        cfg.drain,
    );
    let handle = serve_with(addr, cfg)?;
    println!("pallas server listening on {}", handle.addr);
    println!(
        "protocol: DECOMP <spec> [algo=..] [threads=..] [order=..] [compact=..] [bitsets=..] [validate=..] [timeout=SECS] | HIST <spec> | LOAD <name> <spec> | INSERT <name> <u-v,..> | REMOVE <name> <u-v,..> | UNLOAD <name> | STATUS | METRICS | QUIT"
    );
    println!(
        "replies:  OK ... | ERR BUSY retry_after_ms=N | ERR DEADLINE ... | ERR CANCELLED ... | ERR ..."
    );
    // foreground: block forever (Ctrl-C to stop)
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_lint(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let roots: Vec<String> = if o.positional.is_empty() {
        // default: the crate's own sources, wherever the binary runs from
        ["rust/src", "src"]
            .iter()
            .map(|s| s.to_string())
            .filter(|s| std::path::Path::new(s).is_dir())
            .take(1)
            .collect()
    } else {
        o.positional.clone()
    };
    if roots.is_empty() {
        bail!("no source root found (run from the repo root or pass paths)");
    }
    let mut files = 0usize;
    let mut violations = vec![];
    for root in &roots {
        let out = trussx::lint::lint_tree(std::path::Path::new(root))?;
        files += out.files_scanned;
        violations.extend(out.violations);
    }
    for v in &violations {
        println!("{v}");
    }
    println!(
        "pallas lint: {} file(s) scanned, {} violation(s)",
        files,
        violations.len()
    );
    if violations.is_empty() {
        Ok(())
    } else {
        bail!("lint failed with {} violation(s)", violations.len());
    }
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let spec_str = o.positional.first().context("missing graph spec")?;
    let out = o.get("out").context("missing --out FILE")?;
    let g = GraphSpec::parse(spec_str)?.build()?;
    match std::path::Path::new(out).extension().and_then(|e| e.to_str()) {
        Some("bin") => io::write_binary(&g, out)?,
        _ => io::write_edge_list(&g, out)?,
    }
    println!("wrote {} (n={}, m={})", out, g.n(), g.m());
    Ok(())
}

fn cmd_query(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let spec_str = o.positional.first().context("missing graph spec")?;
    let q: u32 = o.get("vertex").context("missing --vertex V")?.parse()?;
    let g = GraphSpec::parse(spec_str)?.build()?;
    let eg = EdgeGraph::new(g);
    let pool = Pool::with_default_threads();
    let res = trussx::truss::pkt(&eg, &pool);
    let idx = trussx::truss::TrussIndex::new(&eg, res.trussness);
    match o.get("k") {
        Some(kstr) => {
            let k: u32 = kstr.parse().context("bad --k")?;
            let comm = idx.community(q, k);
            println!("{k}-truss community of {q}: {} edges", comm.len());
            for (u, v) in comm.iter().take(50) {
                println!("  {u} {v}");
            }
            if comm.len() > 50 {
                println!("  ... ({} more)", comm.len() - 50);
            }
        }
        None => {
            let (k, comm) = idx.closest_community(q);
            println!(
                "closest community of {q}: k={k}, {} edges (max_k={})",
                comm.len(),
                idx.max_k(q)
            );
        }
    }
    Ok(())
}
