//! Metrics: wall timers, the paper's GWeps performance rate, and a
//! plain-text table formatter used by the bench harness to print the
//! same rows the paper's tables report.

use std::time::Instant;

/// Wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

/// Time a closure over `reps` repetitions, returning (last result,
/// minimum seconds). Minimum-of-N is the standard noise filter for
/// single-machine benchmarking.
pub fn time_best<T>(reps: usize, mut f: impl FnMut() -> T) -> (T, f64) {
    assert!(reps >= 1);
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let t = Timer::start();
        let r = f();
        best = best.min(t.secs());
        out = Some(r);
    }
    (out.unwrap(), best)
}

/// Giga-wedges processed per second — the paper's normalized performance
/// rate (§4.2): wedge count / time / 10⁹.
///
/// Returns `f64::NAN` when `secs` is non-positive or non-finite: a rate
/// over a zero, negative, or unmeasured duration is undefined, and a
/// silent `0.0` would poison downstream aggregates like [`geomean`].
pub fn gweps(wedges: u64, secs: f64) -> f64 {
    if !secs.is_finite() || secs <= 0.0 {
        return f64::NAN;
    }
    wedges as f64 / secs / 1e9
}

/// Geometric mean (the paper summarizes rates and speedups this way).
///
/// Returns `f64::NAN` for an empty slice — the geometric mean of
/// nothing is undefined, and `0.0` would read as "measured and slow".
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Column-aligned plain-text table (markdown-ish, paper-table style).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:>w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gweps_rate() {
        assert!((gweps(2_000_000_000, 2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gweps_undefined_durations_are_nan() {
        assert!(gweps(100, 0.0).is_nan());
        assert!(gweps(100, -1.0).is_nan());
        assert!(gweps(100, f64::NAN).is_nan());
        assert!(gweps(100, f64::INFINITY).is_nan());
        assert!(gweps(0, 1.0) == 0.0, "zero work in finite time is a real rate");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_empty_is_nan() {
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn timer_measures() {
        let (out, secs) = time(|| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(out, 42);
        assert!(secs >= 0.004, "{secs}");
    }

    #[test]
    fn time_best_takes_min() {
        let mut calls = 0;
        let (_, secs) = time_best(3, || {
            calls += 1;
        });
        assert_eq!(calls, 3);
        assert!(secs < 0.1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["graph", "time"]);
        t.row(vec!["k4".into(), "0.1".into()]);
        t.row(vec!["big-one".into(), "12.5".into()]);
        let s = t.render();
        assert!(s.contains("graph"), "{s}");
        assert!(s.lines().count() == 4);
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "aligned: {s}");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
