//! Cohen's original maximal-k-truss algorithm (paper ref [8]) for a
//! *fixed* k — the historical baseline the decomposition generalizes.
//!
//! Repeatedly removes edges with support < k−2, then returns the
//! surviving subgraph's connected components: the maximal k-trusses.
//! Unlike the decomposition (which labels every edge), this answers the
//! single-k query directly — useful when only one cohesion level is
//! needed, and the reference point for the `ktruss_components` API.

use crate::graph::{EdgeGraph, Vertex};

/// Maximal k-trusses by Cohen's peel-to-fixpoint: returns per-component
/// edge lists (canonical u < v), like [`super::ktruss_components`].
pub fn cohen_ktruss(eg: &EdgeGraph, k: u32) -> Vec<Vec<(Vertex, Vertex)>> {
    let g = &eg.g;
    let m = eg.m();
    let need = k.saturating_sub(2);
    let mut alive = vec![true; m];
    let mut support = crate::triangle::support_naive(eg);

    // queue-driven peel: seed with edges under threshold. `queued`
    // deduplicates; an edge only becomes dead (`alive = false`) when it
    // is *processed*, so each destroyed triangle decrements its third
    // edge exactly once.
    let mut queued = vec![false; m];
    let mut queue: Vec<usize> = (0..m).filter(|&e| support[e] < need).collect();
    for &e in &queue {
        queued[e] = true;
    }
    let mut head = 0;
    while head < queue.len() {
        let e = queue[head];
        head += 1;
        alive[e] = false;
        let (u, v) = eg.el[e];
        // every triangle through (u, v) loses this edge: decrement the
        // other two edges' supports
        let (ulo, uhi) = (g.xadj[u as usize], g.xadj[u as usize + 1]);
        let (vlo, vhi) = (g.xadj[v as usize], g.xadj[v as usize + 1]);
        let (mut i, mut j) = (ulo, vlo);
        while i < uhi && j < vhi {
            match g.adj[i].cmp(&g.adj[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    let e3 = eg.eid[i] as usize; // <u, w>
                    let e2 = eg.eid[j] as usize; // <v, w>
                    i += 1;
                    j += 1;
                    if alive[e2] && alive[e3] {
                        for f in [e2, e3] {
                            if support[f] > 0 {
                                support[f] -= 1;
                            }
                            if !queued[f] && support[f] < need {
                                queued[f] = true;
                                queue.push(f);
                            }
                        }
                    }
                }
            }
        }
    }

    // connected components over surviving edges
    let kept: Vec<(Vertex, Vertex)> = (0..m).filter(|&e| alive[e]).map(|e| eg.el[e]).collect();
    if kept.is_empty() {
        return vec![];
    }
    let sub = crate::graph::GraphBuilder::new()
        .num_vertices(eg.n())
        .edges_vec(kept.clone())
        .build();
    let (comp, ncomp) = sub.components();
    let mut out = vec![Vec::new(); ncomp];
    for &(u, v) in &kept {
        out[comp[u as usize] as usize].push((u, v));
    }
    out.retain(|c| !c.is_empty());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::par::Pool;
    use crate::truss;
    use crate::util::forall;

    #[test]
    fn cohen_k4_on_complete_graph() {
        let eg = EdgeGraph::new(gen::complete(6));
        // K6 is a 6-truss: it survives any k <= 6
        for k in [2u32, 4, 6] {
            let t = cohen_ktruss(&eg, k);
            assert_eq!(t.len(), 1, "k={k}");
            assert_eq!(t[0].len(), 15);
        }
        assert!(cohen_ktruss(&eg, 7).is_empty());
    }

    #[test]
    fn cohen_matches_decomposition_components() {
        forall("cohen-eq-decomp", 12, |rng| {
            let n = rng.range(6, 60);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let res = truss::pkt(&eg, &Pool::new(2));
            let tmax = truss::max_trussness(&res.trussness);
            for k in 3..=tmax {
                let a = {
                    let mut c = cohen_ktruss(&eg, k);
                    for comp in &mut c {
                        comp.sort_unstable();
                    }
                    c.sort();
                    c
                };
                let b = {
                    let mut c = truss::ktruss_components(&eg, &res.trussness, k);
                    for comp in &mut c {
                        comp.sort_unstable();
                    }
                    c.sort();
                    c
                };
                assert_eq!(a, b, "k={k}");
            }
        });
    }

    #[test]
    fn cohen_bridge_graph() {
        // two triangles + bridge: 3-truss = the two triangles
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)])
            .build();
        let eg = EdgeGraph::new(g);
        let t3 = cohen_ktruss(&eg, 3);
        assert_eq!(t3.len(), 2);
        let t2 = cohen_ktruss(&eg, 2);
        assert_eq!(t2.len(), 1); // everything survives, one component
    }

    #[test]
    fn cohen_empty_inputs() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        assert!(cohen_ktruss(&eg, 3).is_empty());
    }
}
