//! Truss-based community search — the paper's §1 application [14]
//! (Huang et al., "Approximate closest community search in networks"):
//! given a query vertex and a cohesion level k, return the k-truss
//! community containing it, i.e. the connected component of the k-truss
//! subgraph that touches the query vertex.
//!
//! Built on a precomputed decomposition, queries are answered by a BFS
//! restricted to edges with trussness ≥ k — no re-peeling.

use crate::graph::{EdgeGraph, Vertex};
use std::collections::VecDeque;

/// A queryable index over a truss decomposition.
pub struct TrussIndex<'g> {
    eg: &'g EdgeGraph,
    trussness: Vec<u32>,
}

impl<'g> TrussIndex<'g> {
    pub fn new(eg: &'g EdgeGraph, trussness: Vec<u32>) -> Self {
        assert_eq!(trussness.len(), eg.m());
        Self { eg, trussness }
    }

    /// Trussness of the edge `<u, v>` (None if not an edge).
    pub fn edge_trussness(&self, u: Vertex, v: Vertex) -> Option<u32> {
        self.eg.edge_id(u, v).map(|e| self.trussness[e as usize])
    }

    /// Maximum k such that `q` has at least one incident edge of
    /// trussness ≥ k (the vertex's maximum cohesion level).
    pub fn max_k(&self, q: Vertex) -> u32 {
        let g = &self.eg.g;
        let (lo, hi) = (g.xadj[q as usize], g.xadj[q as usize + 1]);
        (lo..hi)
            .map(|j| self.trussness[self.eg.eid[j] as usize])
            .max()
            .unwrap_or(0)
    }

    /// The k-truss community of query vertex `q`: edges of the connected
    /// component (through trussness ≥ k edges) containing `q`. Empty if
    /// `q` touches no such edge.
    pub fn community(&self, q: Vertex, k: u32) -> Vec<(Vertex, Vertex)> {
        let g = &self.eg.g;
        let n = self.eg.n();
        if (q as usize) >= n {
            return vec![];
        }
        let mut visited = vec![false; n];
        let mut out = Vec::new();
        let mut seen_edge = vec![false; self.eg.m()];
        let mut queue = VecDeque::new();
        visited[q as usize] = true;
        queue.push_back(q);
        while let Some(u) = queue.pop_front() {
            let (lo, hi) = (g.xadj[u as usize], g.xadj[u as usize + 1]);
            for j in lo..hi {
                let e = self.eg.eid[j] as usize;
                if self.trussness[e] < k {
                    continue;
                }
                let v = g.adj[j];
                if !seen_edge[e] {
                    seen_edge[e] = true;
                    out.push(self.eg.el[e]);
                }
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
        out
    }

    /// Any-k community search (the "closest community" flavor): the
    /// community of `q` at the highest k where it is non-empty.
    pub fn closest_community(&self, q: Vertex) -> (u32, Vec<(Vertex, Vertex)>) {
        let k = self.max_k(q);
        if k < 2 {
            return (0, vec![]);
        }
        (k, self.community(q, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::par::Pool;
    use crate::truss;

    fn index(g: crate::graph::Graph) -> (EdgeGraph, Vec<u32>) {
        let eg = EdgeGraph::new(g);
        let t = truss::pkt(&eg, &Pool::new(2)).trussness;
        (eg, t)
    }

    #[test]
    fn community_of_planted_block() {
        // 3 blocks of K10 with no noise: community of any vertex at high
        // k is exactly its block
        let (eg, t) = index(gen::planted_partition(3, 10, 1.0, 0.0, 1));
        let idx = TrussIndex::new(&eg, t);
        let comm = idx.community(5, 10);
        assert_eq!(comm.len(), 45, "K10 has 45 edges");
        assert!(comm.iter().all(|&(u, v)| u < 10 && v < 10));
        // vertex from block 2
        let comm2 = idx.community(25, 10);
        assert!(comm2.iter().all(|&(u, v)| (20..30).contains(&u) && (20..30).contains(&v)));
    }

    #[test]
    fn community_respects_k() {
        // two triangles joined by a bridge: at k=3 the community of 0 is
        // its own triangle; at k=2 it spans everything
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)])
            .build();
        let (eg, t) = index(g);
        let idx = TrussIndex::new(&eg, t);
        assert_eq!(idx.community(0, 3).len(), 3);
        assert_eq!(idx.community(0, 2).len(), 7);
        assert_eq!(idx.community(4, 3).len(), 3);
        assert!(idx.community(0, 4).is_empty());
    }

    #[test]
    fn max_k_and_closest() {
        let (eg, t) = index(gen::complete(6));
        let idx = TrussIndex::new(&eg, t);
        assert_eq!(idx.max_k(0), 6);
        let (k, comm) = idx.closest_community(3);
        assert_eq!(k, 6);
        assert_eq!(comm.len(), 15);
    }

    #[test]
    fn isolated_or_invalid_vertex() {
        let g = crate::graph::GraphBuilder::new().num_vertices(4).edge(0, 1).build();
        let (eg, t) = index(g);
        let idx = TrussIndex::new(&eg, t);
        assert!(idx.community(3, 2).is_empty()); // isolated vertex
        assert!(idx.community(99, 2).is_empty()); // out of range
        assert_eq!(idx.max_k(3), 0);
        assert_eq!(idx.closest_community(3).0, 0);
    }

    #[test]
    fn edge_trussness_lookup() {
        let (eg, t) = index(gen::complete(4));
        let idx = TrussIndex::new(&eg, t);
        assert_eq!(idx.edge_trussness(0, 1), Some(4));
        assert_eq!(idx.edge_trussness(1, 0), Some(4));
        assert_eq!(idx.edge_trussness(0, 0), None);
    }
}
