//! WC — Wang & Cheng's serial truss decomposition (Alg. 1).
//!
//! The sequential baseline: support computation, a counting-sort bucket
//! structure for O(1) edge reordering (the Batagelj–Zaversnik trick
//! applied to edges), and a **hash table** for edge membership/lookup —
//! the very overhead PKT's edge-id representation eliminates. The hash
//! table here is `std::collections::HashMap`, faithful to the paper's
//! characterization of WC's cost profile.

use crate::graph::{EdgeGraph, EdgeId, Vertex};
use crate::truss::{PktStats, TrussResult};
use std::collections::HashMap;
use std::time::Instant;

/// Run WC. Serial by definition (step 6 of Alg. 1 is inherently
/// sequential: edges must be extracted in ascending-support order).
pub fn wc(eg: &EdgeGraph) -> TrussResult {
    let t0 = Instant::now();
    let g = &eg.g;
    let m = eg.m();

    // --- support computation (serial merge-based) ---
    let mut s: Vec<u32> = crate::triangle::support_naive(eg);
    let support_secs = t0.elapsed().as_secs_f64();

    // --- hash table over live edges: (min, max) -> edge id ---
    let mut eh: HashMap<(Vertex, Vertex), EdgeId> = HashMap::with_capacity(m * 2);
    for (e, &(u, v)) in eg.el.iter().enumerate() {
        eh.insert((u, v), e as EdgeId);
    }
    let key = |a: Vertex, b: Vertex| if a < b { (a, b) } else { (b, a) };

    // --- counting-sort bucket structure over supports ---
    let smax = s.iter().copied().max().unwrap_or(0) as usize;
    let mut bin = vec![0usize; smax + 2];
    for &x in &s {
        bin[x as usize + 1] += 1;
    }
    for d in 0..=smax {
        bin[d + 1] += bin[d];
    }
    let mut vert = vec![0 as EdgeId; m]; // edges in support order
    let mut pos = vec![0usize; m];
    {
        let mut cursor = bin.clone();
        for e in 0..m {
            let d = s[e] as usize;
            pos[e] = cursor[d];
            vert[pos[e]] = e as EdgeId;
            cursor[d] += 1;
        }
    }

    // decrement edge f's support by one bucket (only while above k)
    let decrement = |f: usize, k: u32, s: &mut Vec<u32>, vert: &mut Vec<EdgeId>,
                         pos: &mut Vec<usize>, bin: &mut Vec<usize>| {
        if s[f] > k {
            let sf = s[f] as usize;
            let pf = pos[f];
            let pw = bin[sf];
            let w = vert[pw] as usize;
            if f != w {
                vert.swap(pf, pw);
                pos[f] = pw;
                pos[w] = pf;
            }
            bin[sf] += 1;
            s[f] -= 1;
        }
    };

    // --- peel in ascending support order ---
    for i in 0..m {
        let e = vert[i] as usize;
        let k = s[e];
        let (u, v) = eg.el[e];
        // canonical: iterate the smaller-degree endpoint
        let (a, b) = if g.degree(u) <= g.degree(v) { (u, v) } else { (v, u) };
        for &w in g.neighbors(a) {
            if w == b {
                continue;
            }
            // triangle a-b-w exists iff both <b,w> and <a,w> are live
            let Some(&e_bw) = eh.get(&key(b, w)) else { continue };
            let Some(&e_aw) = eh.get(&key(a, w)) else { continue };
            decrement(e_aw as usize, k, &mut s, &mut vert, &mut pos, &mut bin);
            decrement(e_bw as usize, k, &mut s, &mut vert, &mut pos, &mut bin);
        }
        eh.remove(&key(u, v));
    }

    let total = t0.elapsed().as_secs_f64();
    TrussResult {
        trussness: s.iter().map(|&x| x + 2).collect(),
        stats: PktStats {
            support_secs,
            process_secs: total - support_secs,
            total_secs: total,
            ..Default::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::par::Pool;
    use crate::truss::pkt;
    use crate::util::forall;

    #[test]
    fn wc_complete_graph() {
        for n in [3usize, 5, 8] {
            let eg = EdgeGraph::new(gen::complete(n));
            let t = wc(&eg).trussness;
            assert!(t.iter().all(|&x| x as usize == n));
        }
    }

    #[test]
    fn wc_matches_pkt() {
        forall("wc-eq-pkt", 12, |rng| {
            let n = rng.range(4, 70);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            assert_eq!(wc(&eg).trussness, pkt(&eg, &Pool::new(2)).trussness);
        });
    }

    #[test]
    fn wc_matches_pkt_clustered() {
        let g = gen::planted_partition(4, 14, 0.75, 0.02, 9);
        let eg = EdgeGraph::new(g);
        assert_eq!(wc(&eg).trussness, pkt(&eg, &Pool::new(4)).trussness);
    }

    #[test]
    fn wc_empty_and_single_edge() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        assert!(wc(&eg).trussness.is_empty());
        let eg = EdgeGraph::new(GraphBuilder::new().edge(0, 1).build());
        assert_eq!(wc(&eg).trussness, vec![2]);
    }
}
