//! k-truss decomposition — the paper's contribution and its baselines.
//!
//! All algorithms return the **trussness** of every edge (the paper's
//! `S[e] + 2` convention): edge `e` has trussness `t` if it belongs to a
//! t-truss but not a (t+1)-truss. Algorithms:
//!
//! - [`pkt`] — the paper's PKT: level-synchronous parallel peeling
//!   (Alg. 4 + 5), AM4 support computation, frontier buffers, triangle
//!   ownership rule;
//! - [`wc`] — Wang–Cheng serial peeling with a hash table (Alg. 1), the
//!   sequential baseline;
//! - [`ros`] — Rossi: parallel support computation (Alg. 2) + serial
//!   hash-free peeling over the edge-id representation;
//! - [`local`] — h-index local-update iteration (Sariyüce et al. [19] /
//!   MPM [34] style), the synchronization-free alternative;
//! - `dense` — XLA dense-block decomposition through the AOT
//!   Pallas/JAX artifacts (the Graphulo-style linear-algebra sibling);
//!   only built with the off-by-default `xla` cargo feature.
//!
//! [`DynamicTruss`] keeps a decomposition correct under batch edge
//! insertions/deletions by re-peeling only the affected triangle-
//! connected region (frozen-context region peel; see `dynamic`).

mod cohen;
mod dynamic;
mod local;
mod pkt;
mod query;
mod ros;
mod wc;
#[cfg(feature = "xla")]
pub mod dense;
pub mod external;

pub use cohen::cohen_ktruss;
pub use dynamic::{DynamicTruss, UpdateOp, UpdateReport};
pub use local::local;
pub use pkt::{
    pkt, pkt_config, pkt_config_with, pkt_with_support, pkt_with_support_config,
    pkt_with_support_config_with, LevelStat, PktConfig, PktStats, TrussResult,
};
pub use query::TrussIndex;
pub use ros::ros;
pub use wc::wc;

use crate::graph::{EdgeGraph, Graph, GraphBuilder, Vertex};

/// Maximum trussness over all edges (`t_max` in Table 1); 0 on empty.
pub fn max_trussness(trussness: &[u32]) -> u32 {
    trussness.iter().copied().max().unwrap_or(0)
}

/// Histogram of k-class sizes: `hist[k]` = number of edges of trussness
/// k (index 0 and 1 unused; trussness starts at 2).
pub fn class_histogram(trussness: &[u32]) -> Vec<u64> {
    let tmax = max_trussness(trussness) as usize;
    let mut hist = vec![0u64; tmax + 1];
    for &t in trussness {
        hist[t as usize] += 1;
    }
    hist
}

/// Extract the maximal k-truss subgraphs for a specific `k`: the
/// subgraph on edges with trussness ≥ k, split into connected
/// components. Returns per-component edge lists (canonical u < v).
pub fn ktruss_components(
    eg: &EdgeGraph,
    trussness: &[u32],
    k: u32,
) -> Vec<Vec<(Vertex, Vertex)>> {
    assert_eq!(trussness.len(), eg.m());
    // build the filtered subgraph
    let kept: Vec<(Vertex, Vertex)> = eg
        .el
        .iter()
        .zip(trussness)
        .filter(|&(_, &t)| t >= k)
        .map(|(&e, _)| e)
        .collect();
    if kept.is_empty() {
        return vec![];
    }
    let sub: Graph = GraphBuilder::new()
        .num_vertices(eg.n())
        .edges_vec(kept.clone())
        .build();
    let (comp, ncomp) = sub.components();
    let mut out = vec![Vec::new(); ncomp];
    for &(u, v) in &kept {
        out[comp[u as usize] as usize].push((u, v));
    }
    // drop singleton components (isolated vertices have no edges and
    // produce empty lists)
    out.retain(|c| !c.is_empty());
    out
}

/// Verify a decomposition against the k-truss definition (test oracle,
/// O(t_max · m^1.5) — small graphs only): for every k-class, each edge of
/// the k-truss subgraph must have ≥ k−2 triangles *within* the subgraph,
/// and edges of trussness k must fail that bound in the (k+1)-subgraph.
pub fn verify_definition(eg: &EdgeGraph, trussness: &[u32]) -> Result<(), String> {
    let tmax = max_trussness(trussness);
    for k in 2..=tmax {
        // subgraph on edges with trussness >= k
        let kept: Vec<(Vertex, Vertex)> = eg
            .el
            .iter()
            .zip(trussness)
            .filter(|&(_, &t)| t >= k)
            .map(|(&e, _)| e)
            .collect();
        let sub = GraphBuilder::new().num_vertices(eg.n()).edges_vec(kept).build();
        let sub_eg = EdgeGraph::new(sub);
        let s = crate::triangle::support_naive(&sub_eg);
        for (i, &(u, v)) in sub_eg.el.iter().enumerate() {
            if (s[i] as u64) < (k as u64 - 2) {
                return Err(format!(
                    "edge <{u},{v}> in {k}-truss subgraph has support {} < {}",
                    s[i],
                    k - 2
                ));
            }
        }
    }
    // maximality: each edge with trussness k must NOT survive in the
    // (k+1)-peeled subgraph — implied by running a reference peel; the
    // cross-algorithm equality tests cover this, and the bound above
    // covers soundness.
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::par::Pool;

    #[test]
    fn histogram_and_max() {
        let t = vec![2, 2, 3, 3, 3, 4];
        assert_eq!(max_trussness(&t), 4);
        let h = class_histogram(&t);
        assert_eq!(h[2], 2);
        assert_eq!(h[3], 3);
        assert_eq!(h[4], 1);
    }

    #[test]
    fn ktruss_components_two_triangles() {
        // Figure 1-style: two 3-trusses joined by trussness-2 edges
        let g = GraphBuilder::new()
            .edges(&[
                (0, 1), (0, 2), (1, 2), // triangle A
                (3, 4), (3, 5), (4, 5), // triangle B
                (2, 3), // bridge
            ])
            .build();
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(1));
        let comps = ktruss_components(&eg, &res.trussness, 3);
        assert_eq!(comps.len(), 2, "{comps:?}");
        let comps2 = ktruss_components(&eg, &res.trussness, 2);
        assert_eq!(comps2.len(), 1);
        assert!(ktruss_components(&eg, &res.trussness, 4).is_empty());
    }

    #[test]
    fn verify_definition_accepts_correct() {
        let g = gen::planted_partition(3, 10, 0.8, 0.05, 5);
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        verify_definition(&eg, &res.trussness).unwrap();
    }

    #[test]
    fn verify_definition_rejects_wrong() {
        let eg = EdgeGraph::new(gen::complete(5));
        // K5: true trussness is 5 everywhere; claim 6 → soundness breaks
        let wrong = vec![6u32; eg.m()];
        assert!(verify_definition(&eg, &wrong).is_err());
    }
}
