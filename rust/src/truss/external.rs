//! Bounded-memory truss listing — Wang & Cheng's external-memory
//! bottom-up and top-down algorithms (paper §2, ref [16]).
//!
//! The originals stream partitions from disk; here the "memory" budget
//! bounds the *working subgraph* (edges materialized at once), which is
//! what the algorithms actually economize. Both prune with the trussness
//! upper bound `ub(e) = min(S₀(e) + 2, core(u) + 1, core(v) + 1)`
//! (initial support bounds the trussness; a k-truss lives inside the
//! (k−1)-core):
//!
//! - **bottom-up** lists the k-classes for k = 2, 3, … — each round
//!   materializes only edges with `ub ≥ k`, which shrinks as k grows;
//! - **top-down** answers "give me the k_q-truss for a large k_q"
//!   directly: it materializes only edges with `ub ≥ k_q`, never the
//!   full graph — the paper's observation that top-down is preferable
//!   when only high-k trusses are wanted.

use crate::graph::{EdgeGraph, GraphBuilder, Vertex};
use crate::kcore;

/// Statistics from a bounded-memory run (for the budget assertions and
/// the external-memory trade-off bench).
#[derive(Clone, Debug, Default)]
pub struct ExternalStats {
    /// Largest number of edges materialized at once.
    pub peak_edges: usize,
    /// Total edges loaded across all rounds (I/O proxy).
    pub edges_loaded: usize,
    /// Rounds (subgraph constructions) performed.
    pub rounds: usize,
}

/// Trussness upper bound per edge.
fn upper_bounds(eg: &EdgeGraph) -> Vec<u32> {
    let core = kcore::bz(&eg.g);
    let s0 = crate::triangle::support_naive(eg);
    eg.el
        .iter()
        .zip(&s0)
        .map(|(&(u, v), &s)| {
            (s + 2)
                .min(core[u as usize] + 1)
                .min(core[v as usize] + 1)
        })
        .collect()
}

/// Peel the subgraph on `edges` to its k-truss; returns surviving edges.
fn ktruss_of_subgraph(
    n: usize,
    edges: Vec<(Vertex, Vertex)>,
    k: u32,
) -> Vec<(Vertex, Vertex)> {
    if edges.is_empty() {
        return edges;
    }
    let sub = GraphBuilder::new().num_vertices(n).edges_vec(edges).build();
    let sub_eg = EdgeGraph::new(sub);
    super::cohen_ktruss(&sub_eg, k).into_iter().flatten().collect()
}

/// Bottom-up listing: returns the trussness of every edge (equal to the
/// decomposition) while never materializing more than the `ub ≥ k`
/// subgraph per round. Errors if any round exceeds `budget_edges`.
pub fn bottom_up(
    eg: &EdgeGraph,
    budget_edges: usize,
) -> Result<(Vec<u32>, ExternalStats), String> {
    let m = eg.m();
    let ub = upper_bounds(eg);
    let mut trussness = vec![2u32; m];
    let mut stats = ExternalStats::default();
    let kmax = ub.iter().copied().max().unwrap_or(2);
    for k in 3..=kmax {
        // working set: edges that could still be in a k-truss
        let cand: Vec<(Vertex, Vertex)> = (0..m)
            .filter(|&e| ub[e] >= k)
            .map(|e| eg.el[e])
            .collect();
        stats.rounds += 1;
        stats.edges_loaded += cand.len();
        stats.peak_edges = stats.peak_edges.max(cand.len());
        if cand.len() > budget_edges {
            return Err(format!(
                "round k={k}: working set {} exceeds budget {budget_edges}",
                cand.len()
            ));
        }
        if cand.is_empty() {
            break;
        }
        let survivors = ktruss_of_subgraph(eg.n(), cand, k);
        // surviving edges have trussness >= k
        for (u, v) in survivors {
            let e = eg.edge_id(u, v).expect("edge") as usize;
            trussness[e] = k;
        }
    }
    Ok((trussness, stats))
}

/// Top-down query: the maximal k_q-truss edge set, materializing only
/// the `ub ≥ k_q` candidates. Returns (edges, stats).
pub fn top_down(
    eg: &EdgeGraph,
    k_q: u32,
    budget_edges: usize,
) -> Result<(Vec<(Vertex, Vertex)>, ExternalStats), String> {
    let m = eg.m();
    let ub = upper_bounds(eg);
    let cand: Vec<(Vertex, Vertex)> = (0..m)
        .filter(|&e| ub[e] >= k_q)
        .map(|e| eg.el[e])
        .collect();
    let stats = ExternalStats {
        peak_edges: cand.len(),
        edges_loaded: cand.len(),
        rounds: 1,
    };
    if cand.len() > budget_edges {
        return Err(format!(
            "working set {} exceeds budget {budget_edges}",
            cand.len()
        ));
    }
    Ok((ktruss_of_subgraph(eg.n(), cand, k_q), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::par::Pool;
    use crate::truss;
    use crate::util::forall;

    #[test]
    fn bottom_up_matches_pkt() {
        forall("external-bottomup", 10, |rng| {
            let n = rng.range(6, 60);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let (t, stats) = bottom_up(&eg, usize::MAX).unwrap();
            let p = truss::pkt(&eg, &Pool::new(2)).trussness;
            assert_eq!(t, p);
            assert!(stats.peak_edges <= eg.m());
        });
    }

    #[test]
    fn top_down_matches_components() {
        let g = gen::planted_partition(3, 14, 0.85, 0.02, 6);
        let eg = EdgeGraph::new(g);
        let res = truss::pkt(&eg, &Pool::new(2));
        let tmax = truss::max_trussness(&res.trussness);
        let (edges, stats) = top_down(&eg, tmax, usize::MAX).unwrap();
        let mut want: Vec<(Vertex, Vertex)> = truss::ktruss_components(&eg, &res.trussness, tmax)
            .into_iter()
            .flatten()
            .collect();
        let mut got = edges;
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want);
        // the top-down working set is a strict subset of the graph
        assert!(stats.peak_edges < eg.m());
    }

    #[test]
    fn top_down_touches_less_for_high_k() {
        // the paper's trade-off: querying only a high-k truss loads far
        // fewer edges than a full bottom-up listing
        let g = gen::planted_partition(4, 16, 0.8, 0.01, 7);
        let eg = EdgeGraph::new(g);
        let res = truss::pkt(&eg, &Pool::new(2));
        let tmax = truss::max_trussness(&res.trussness);
        let (_, td) = top_down(&eg, tmax, usize::MAX).unwrap();
        let (_, bu) = bottom_up(&eg, usize::MAX).unwrap();
        assert!(
            td.edges_loaded < bu.edges_loaded / 2,
            "top-down {} vs bottom-up {}",
            td.edges_loaded,
            bu.edges_loaded
        );
    }

    #[test]
    fn budget_enforced() {
        let eg = EdgeGraph::new(gen::complete(12));
        assert!(bottom_up(&eg, 5).is_err());
        assert!(top_down(&eg, 3, 5).is_err());
        assert!(top_down(&eg, 3, 100).is_ok());
    }

    #[test]
    fn shrinking_working_set() {
        // bottom-up rounds must be monotone non-increasing in size
        let g = gen::barabasi_albert(150, 4, 8);
        let eg = EdgeGraph::new(g);
        let (_, stats) = bottom_up(&eg, usize::MAX).unwrap();
        assert!(stats.rounds >= 1);
        assert!(stats.peak_edges <= eg.m());
    }

    #[test]
    fn empty_graph() {
        let eg = EdgeGraph::new(crate::graph::GraphBuilder::new().build());
        let (t, _) = bottom_up(&eg, 10).unwrap();
        assert!(t.is_empty());
        let (e, _) = top_down(&eg, 3, 10).unwrap();
        assert!(e.is_empty());
    }
}
