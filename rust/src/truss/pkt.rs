//! PKT — the paper's parallel k-truss decomposition (Alg. 4 + 5).
//!
//! Level-synchronous peeling in "support space": level `l` processes the
//! edges whose remaining support is `l`; their trussness is `l + 2`.
//! Within a level, sub-levels expand the frontier until closure, exactly
//! like ParK does for k-core. Three shared structures carry the state
//! across threads: the atomic support array `S`, the `processed` flags,
//! and the flip-flopped `curr`/`next` frontiers with their `inCurr` /
//! `inNext` membership flags. A triangle whose two unprocessed edges are
//! both in the frontier is claimed by the thread holding the *lower*
//! edge id (the paper's ownership rule, Fig. 3), so every triangle is
//! processed exactly once — the work-efficiency argument of §3.
//!
//! Two memory-traffic optimizations layer on top of the paper's
//! algorithm, both configurable through [`PktConfig`]:
//!
//! - **packed flags** (`use_bitsets`): `processed`/`inCurr`/`inNext` are
//!   [`AtomicBitset`]s (1 bit/edge) instead of byte-wide `AtomicBool`
//!   arrays — 8× less flag memory and SCAN bandwidth;
//! - **active-graph compaction** (`compact_threshold`): the peel runs in
//!   *stages*; when the live fraction drops below the threshold
//!   (re-checked after every level) the stage ends and the surviving
//!   edges are rebuilt into a relabeled sub-[`EdgeGraph`]
//!   ([`crate::graph::compact_edges`]), so SCAN and triangle enumeration
//!   only touch live adjacency from then on. Because edge ids stay
//!   lexicographic under compaction, the ownership rule is unaffected.

use crate::graph::{compact_edges, EdgeGraph, EdgeId};
use crate::obs;
use crate::par::cancel::{CancelToken, Cancelled};
use crate::par::{AtomicBitset, AtomicVec, BatchWriter, Counter, Pool, CHUNK_PROCESS};
use crate::triangle::support_am4_with;
use crate::par::sync::atomic::{AtomicBool, AtomicI32, AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Tuning knobs for the peel. `Default` enables both optimizations.
#[derive(Clone, Copy, Debug)]
pub struct PktConfig {
    /// Rebuild a compacted sub-graph when `live_edges < threshold * m`
    /// (of the current stage's graph), re-checked after each level.
    /// `0.0` disables compaction; `1.0` rebuilds after every level that
    /// peeled anything. Values are clamped to `[0, 1]`.
    pub compact_threshold: f64,
    /// Use bit-packed flag arrays instead of byte-wide `AtomicBool`s.
    pub use_bitsets: bool,
}

impl Default for PktConfig {
    fn default() -> Self {
        Self { compact_threshold: 0.3, use_bitsets: true }
    }
}

/// Per-level timing/size record (drives Fig. 6).
#[derive(Clone, Debug)]
pub struct LevelStat {
    /// Support level `l`; the edges peeled here have trussness `l + 2`.
    pub level: u32,
    /// Edges peeled at this level.
    pub edges: u64,
    /// Sub-levels needed to close the level.
    pub sublevels: u32,
    /// Wall time spent processing this level (scan + all sub-levels).
    pub secs: f64,
}

/// Phase breakdown and level statistics for one PKT run (Figs. 4–6).
///
/// Every duration here is derived from `obs` spans (`pkt.support`,
/// `pkt.peel`, `pkt.scan`, `pkt.process`, `pkt.level`, `pkt.compact`),
/// so the struct always agrees with what the registry histograms and the
/// trace sink record for the same run.
#[derive(Clone, Debug, Default)]
pub struct PktStats {
    pub support_secs: f64,
    pub scan_secs: f64,
    pub process_secs: f64,
    /// Sum of all `pkt.level` span durations, including levels that
    /// peeled nothing (unlike `per_level`, which keeps only non-empty
    /// levels for Fig. 6).
    pub levels_secs: f64,
    pub total_secs: f64,
    pub levels: u32,
    pub sublevels: u64,
    pub per_level: Vec<LevelStat>,
    /// Active-graph compaction rebuilds performed during the peel.
    pub rebuilds: u32,
    /// Wall time spent inside those rebuilds (`pkt.compact` spans).
    pub compact_secs: f64,
    /// Total edges visited by SCAN across all levels — the bandwidth
    /// proxy that compaction reduces (without it this is `m · levels`).
    pub scanned_edges: u64,
}

/// Result of a truss decomposition run.
#[derive(Clone, Debug)]
pub struct TrussResult {
    /// Trussness per edge id (`S[e] + 2` in the paper's convention).
    pub trussness: Vec<u32>,
    pub stats: PktStats,
}

/// Cached handles into the global metric registry for the peel's
/// compaction instrumentation (same pattern as `par::par_obs`).
struct PktObs {
    rebuilds: obs::Counter,
    live_edges: obs::Gauge,
    scanned: obs::Counter,
}

fn pkt_obs() -> &'static PktObs {
    static OBS: OnceLock<PktObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        PktObs {
            rebuilds: r.counter("pkt_rebuilds_total", &[]),
            live_edges: r.gauge("pkt_live_edges", &[]),
            scanned: r.counter("pkt_scanned_edges_total", &[]),
        }
    })
}

/// Run PKT with the default configuration: AM4 support computation
/// followed by level-synchronous parallel peeling.
pub fn pkt(eg: &EdgeGraph, pool: &Pool) -> TrussResult {
    pkt_config(eg, pool, &PktConfig::default())
}

/// Run PKT with an explicit [`PktConfig`].
pub fn pkt_config(eg: &EdgeGraph, pool: &Pool, cfg: &PktConfig) -> TrussResult {
    match pkt_config_with(eg, pool, cfg, &CancelToken::never()) {
        Ok(res) => res,
        // a never-token cannot stop the decomposition
        Err(c) => unreachable!("pkt cancelled without a token: {c}"),
    }
}

/// [`pkt_config`] with cooperative cancellation: the token is polled at
/// the support phase's chunk boundaries and at the peel's level
/// boundaries, the paper's natural synchronization points. On stop the
/// job unwinds with a [`Cancelled`] error carrying partial progress
/// (levels completed, edges peeled) instead of a half-built result.
pub fn pkt_config_with(
    eg: &EdgeGraph,
    pool: &Pool,
    cfg: &PktConfig,
    token: &CancelToken,
) -> Result<TrussResult, Cancelled> {
    let sp = obs::span("pkt.support");
    let s_u32 = support_am4_with(eg, pool, token)?;
    let support_secs = sp.close();
    let s: Vec<AtomicI32> = s_u32
        .into_iter()
        .map(|a| AtomicI32::new(a.into_inner() as i32))
        .collect();
    let mut res = pkt_with_support_config_with(eg, pool, s, cfg, token)?;
    res.stats.support_secs = support_secs;
    res.stats.total_secs += support_secs;
    Ok(res)
}

/// The peeling phase of PKT, given a precomputed atomic support array.
/// Exposed separately so benches can ablate the support method (AM4 vs
/// Ros) inside the same peel.
pub fn pkt_with_support(eg: &EdgeGraph, pool: &Pool, s: Vec<AtomicI32>) -> TrussResult {
    pkt_with_support_config(eg, pool, s, &PktConfig::default())
}

/// The peeling phase with an explicit [`PktConfig`].
pub fn pkt_with_support_config(
    eg: &EdgeGraph,
    pool: &Pool,
    s: Vec<AtomicI32>,
    cfg: &PktConfig,
) -> TrussResult {
    match pkt_with_support_config_with(eg, pool, s, cfg, &CancelToken::never()) {
        Ok(res) => res,
        // a never-token cannot stop the peel
        Err(c) => unreachable!("pkt peel cancelled without a token: {c}"),
    }
}

/// The peeling phase with an explicit [`PktConfig`] and a [`CancelToken`]
/// polled at level boundaries.
pub fn pkt_with_support_config_with(
    eg: &EdgeGraph,
    pool: &Pool,
    s: Vec<AtomicI32>,
    cfg: &PktConfig,
    token: &CancelToken,
) -> Result<TrussResult, Cancelled> {
    let sp_peel = obs::span("pkt.peel");
    let threshold = cfg.compact_threshold.clamp(0.0, 1.0);
    let driven = if cfg.use_bitsets {
        peel_driver::<AtomicBitset>(eg, pool, s, threshold, token, None)
    } else {
        peel_driver::<BoolFlags>(eg, pool, s, threshold, token, None)
    };
    let (trussness, mut stats) = driven?;
    stats.total_secs = sp_peel.close();
    Ok(TrussResult { trussness, stats })
}

/// Region re-peel for batch-dynamic maintenance
/// ([`crate::truss::DynamicTruss`]): peel a sub-[`EdgeGraph`] in which
/// some edges are *frozen* — their support is pinned at `trussness - 2`
/// and [`decrement`] never touches it. Frozen edges still enter the
/// frontier at their pinned level and still decrement their unfrozen
/// triangle partners, so they replay exactly the influence they exert
/// in a full peel without being recomputed themselves.
pub(crate) fn pkt_region_peel(
    eg: &EdgeGraph,
    pool: &Pool,
    s: Vec<AtomicI32>,
    frozen: AtomicBitset,
    cfg: &PktConfig,
    token: &CancelToken,
) -> Result<TrussResult, Cancelled> {
    let sp_peel = obs::span("pkt.peel");
    let threshold = cfg.compact_threshold.clamp(0.0, 1.0);
    let driven = if cfg.use_bitsets {
        peel_driver::<AtomicBitset>(eg, pool, s, threshold, token, Some(frozen))
    } else {
        peel_driver::<BoolFlags>(eg, pool, s, threshold, token, Some(frozen))
    };
    let (trussness, mut stats) = driven?;
    stats.total_secs = sp_peel.close();
    Ok(TrussResult { trussness, stats })
}

/// The peel's flag-array abstraction: bit-packed or byte-wide, selected
/// by `PktConfig::use_bitsets` and monomorphized into the stage loop so
/// the hot path carries no dynamic dispatch. Relaxed ordering throughout
/// — cross-phase visibility comes from the region barriers.
trait FlagArray: Sync {
    fn with_len(len: usize) -> Self;
    fn get(&self, i: usize) -> bool;
    fn set(&self, i: usize);
    fn clear(&self, i: usize);
}

impl FlagArray for AtomicBitset {
    fn with_len(len: usize) -> Self {
        AtomicBitset::new(len)
    }
    #[inline]
    fn get(&self, i: usize) -> bool {
        AtomicBitset::get(self, i)
    }
    #[inline]
    fn set(&self, i: usize) {
        AtomicBitset::set(self, i)
    }
    #[inline]
    fn clear(&self, i: usize) {
        AtomicBitset::clear(self, i)
    }
}

/// The pre-compaction representation: one byte per flag.
struct BoolFlags(Vec<AtomicBool>);

impl FlagArray for BoolFlags {
    fn with_len(len: usize) -> Self {
        Self((0..len).map(|_| AtomicBool::new(false)).collect())
    }
    #[inline]
    fn get(&self, i: usize) -> bool {
        self.0[i].load(Ordering::Relaxed)
    }
    #[inline]
    fn set(&self, i: usize) {
        self.0[i].store(true, Ordering::Relaxed);
    }
    #[inline]
    fn clear(&self, i: usize) {
        self.0[i].store(false, Ordering::Relaxed);
    }
}

/// Stat accumulators shared across stages (tid-0 fed, barrier-separated
/// — same discipline as the single-region peel had).
struct PeelShared {
    todo: AtomicI64,
    scan_ns: AtomicU64,
    process_ns: AtomicU64,
    levels_ns: AtomicU64,
    sublevel_count: AtomicU64,
    level_count: AtomicU64,
    scanned_edges: AtomicU64,
    per_level: Mutex<Vec<LevelStat>>,
}

/// The staged peel. Each stage is one parallel region over the current
/// (possibly compacted) graph; between stages, the main thread rebuilds
/// the active sub-graph and remaps the support array. Trussness is
/// accumulated in the *original* edge-id space through `cur_to_orig`.
fn peel_driver<F: FlagArray>(
    eg: &EdgeGraph,
    pool: &Pool,
    s: Vec<AtomicI32>,
    threshold: f64,
    token: &CancelToken,
    frozen: Option<AtomicBitset>,
) -> Result<(Vec<u32>, PktStats), Cancelled> {
    let m_orig = eg.m();
    let shared = PeelShared {
        todo: AtomicI64::new(m_orig as i64),
        scan_ns: AtomicU64::new(0),
        process_ns: AtomicU64::new(0),
        levels_ns: AtomicU64::new(0),
        sublevel_count: AtomicU64::new(0),
        level_count: AtomicU64::new(0),
        scanned_edges: AtomicU64::new(0),
        per_level: Mutex::new(Vec::new()),
    };

    // final support per ORIGINAL edge id; stages write their peeled
    // edges here as they finish
    let mut final_s: Vec<i32> = vec![0; m_orig];
    // current-stage id → original id; `None` means identity (no rebuild
    // has happened yet)
    let mut cur_to_orig: Option<Vec<EdgeId>> = None;
    let mut owned: Option<EdgeGraph> = None;
    let mut s = s;
    let mut frozen = frozen;
    let mut rebuilds = 0u32;
    let mut compact_secs = 0.0f64;

    let mut interrupted = false;
    loop {
        let cur: &EdgeGraph = owned.as_ref().unwrap_or(eg);
        let m = cur.m();
        // levels are numbered globally: the next stage resumes where the
        // previous one stopped
        let start_level = shared.level_count.load(Ordering::Relaxed) as i32;
        let processed = F::with_len(m);
        let in_a = F::with_len(m);
        let in_b = F::with_len(m);
        run_stage(
            cur,
            pool,
            &s,
            &processed,
            &in_a,
            &in_b,
            &shared,
            threshold,
            start_level,
            token,
            frozen.as_ref(),
        );

        if shared.todo.load(Ordering::Acquire) <= 0 {
            // everything in the current graph is peeled; supports are
            // frozen at the peel level of each edge
            for e in 0..m {
                let orig = match &cur_to_orig {
                    None => e,
                    Some(map) => map[e] as usize,
                };
                final_s[orig] = s[e].load(Ordering::Relaxed);
            }
            break;
        }

        // completion wins over a stop observed on the same boundary; a
        // stage that exits with work remaining did so either for a
        // compaction rebuild or because tid 0 saw the token fire at a
        // level boundary — re-checking the token here distinguishes them
        // (once fired it stays fired: the flag is sticky and a passed
        // deadline stays passed)
        if token.should_stop().is_some() {
            interrupted = true;
            break;
        }

        // live fraction dropped below the threshold: record the peeled
        // edges of this stage, then rebuild on the survivors
        let sp = obs::span("pkt.compact");
        for e in 0..m {
            if processed.get(e) {
                let orig = match &cur_to_orig {
                    None => e,
                    Some(map) => map[e] as usize,
                };
                final_s[orig] = s[e].load(Ordering::Relaxed);
            }
        }
        let comp = compact_edges(cur, pool, |e| !processed.get(e as usize));
        if crate::validate::enabled() {
            let mut rep = crate::validate::Report::new();
            crate::validate::check_compaction(cur, &comp, |e| !processed.get(e as usize), &mut rep);
            rep.panic_if_failed("pkt compaction");
        }
        s = comp
            .old_of_new
            .iter()
            .map(|&o| AtomicI32::new(s[o as usize].load(Ordering::Relaxed)))
            .collect();
        frozen = frozen.map(|old| {
            // frozen bits ride the same old→new remap as the supports
            let next = AtomicBitset::new(comp.old_of_new.len());
            for (new, &o) in comp.old_of_new.iter().enumerate() {
                if old.get(o as usize) {
                    next.set(new);
                }
            }
            next
        });
        cur_to_orig = Some(match cur_to_orig {
            None => comp.old_of_new.clone(),
            Some(map) => comp.old_of_new.iter().map(|&o| map[o as usize]).collect(),
        });
        owned = Some(comp.eg);
        rebuilds += 1;
        compact_secs += sp.close();
        pkt_obs().rebuilds.inc();
    }

    if interrupted {
        // partial-stats reporting: how far the peel got before the stop
        let remaining = shared.todo.load(Ordering::Acquire).max(0) as u64;
        let levels = shared.level_count.load(Ordering::Relaxed);
        return Err(token.stopped(
            "pkt.level",
            format!("levels={} peeled={}/{}", levels, m_orig as u64 - remaining, m_orig),
        ));
    }

    let trussness: Vec<u32> = final_s.iter().map(|&v| (v + 2) as u32).collect();
    let stats = PktStats {
        support_secs: 0.0,
        scan_secs: shared.scan_ns.into_inner() as f64 * 1e-9,
        process_secs: shared.process_ns.into_inner() as f64 * 1e-9,
        levels_secs: shared.levels_ns.into_inner() as f64 * 1e-9,
        total_secs: 0.0, // filled by the caller from the pkt.peel span
        levels: shared.level_count.into_inner() as u32,
        sublevels: shared.sublevel_count.into_inner(),
        per_level: shared.per_level.into_inner().unwrap(),
        rebuilds,
        compact_secs,
        scanned_edges: shared.scanned_edges.into_inner(),
    };
    Ok((trussness, stats))
}

/// One peel stage: a parallel region running levels on the current graph
/// until all edges are done (`todo == 0`), tid 0 requests a compaction
/// rebuild (live fraction below threshold at a level boundary), or tid 0
/// observes the cancel token fire (also checked only at level
/// boundaries, so a level in flight always completes).
#[allow(clippy::too_many_arguments)]
fn run_stage<F: FlagArray>(
    eg: &EdgeGraph,
    pool: &Pool,
    s: &[AtomicI32],
    processed: &F,
    in_a: &F,
    in_b: &F,
    shared: &PeelShared,
    threshold: f64,
    start_level: i32,
    token: &CancelToken,
    frozen: Option<&AtomicBitset>,
) {
    let n = eg.n();
    let m = eg.m();
    let g = &eg.g;
    let front_a: AtomicVec<EdgeId> = AtomicVec::with_capacity(m);
    let front_b: AtomicVec<EdgeId> = AtomicVec::with_capacity(m);
    let proc_counter = Counter::new();
    let want_compact = AtomicBool::new(false);
    let want_stop = AtomicBool::new(false);
    let metrics = pkt_obs();

    pool.region(|ctx| {
        let mut x = vec![0u32; n]; // thread-local marking array (u32 slots: cache-friendlier)
        let mut level: i32 = start_level;
        while shared.todo.load(Ordering::Acquire) > 0 {
            let mut sp_level: Option<obs::Span> = None;
            let mut sp_scan: Option<obs::Span> = None;
            if ctx.tid == 0 {
                let lvl = level.to_string();
                sp_level = Some(obs::span_with("pkt.level", &[("level", &lvl)]));
                sp_scan = Some(obs::span("pkt.scan"));
            }
            // ---- SCAN: static schedule over S (paper §4.1) ----
            {
                let mut w = BatchWriter::new(&front_a);
                let (lo, hi) = ctx.static_range(m);
                for e in lo..hi {
                    if !processed.get(e) && s[e].load(Ordering::Relaxed) == level {
                        in_a.set(e);
                        w.push(e as EdgeId);
                    }
                }
            }
            ctx.barrier();
            if let Some(sp) = sp_scan {
                shared.scan_ns.fetch_add(secs_to_ns(sp.close()), Ordering::Relaxed);
            }

            // ---- sub-level expansion ----
            let mut flip = false;
            let mut level_edges = 0u64;
            let mut level_subs = 0u32;
            loop {
                let (cur, cur_in, nxt, nxt_in) = if !flip {
                    (&front_a, in_a, &front_b, in_b)
                } else {
                    (&front_b, in_b, &front_a, in_a)
                };
                let cur_len = cur.len();
                if cur_len == 0 {
                    break;
                }
                level_edges += cur_len as u64;
                level_subs += 1;
                if ctx.tid == 0 {
                    shared.todo.fetch_sub(cur_len as i64, Ordering::AcqRel);
                    shared.sublevel_count.fetch_add(1, Ordering::Relaxed);
                }
                let sp_proc = if ctx.tid == 0 { Some(obs::span("pkt.process")) } else { None };
                {
                    let cur_slice = cur.as_slice();
                    let mut w = BatchWriter::new(nxt);
                    ctx.for_dynamic(&proc_counter, cur_len, CHUNK_PROCESS, |i| {
                        let e1 = cur_slice[i];
                        process_edge(
                            eg, g, e1, level, s, processed, cur_in, nxt_in, &mut w, &mut x,
                            frozen,
                        );
                    });
                }
                ctx.barrier();
                if let Some(sp) = sp_proc {
                    shared.process_ns.fetch_add(secs_to_ns(sp.close()), Ordering::Relaxed);
                }
                // retire the current frontier: mark processed, clear flags
                {
                    let cur_slice = cur.as_slice();
                    ctx.for_static(cur_len, |i| {
                        let e = cur_slice[i] as usize;
                        processed.set(e);
                        cur_in.clear(e);
                    });
                }
                ctx.barrier();
                if ctx.tid == 0 {
                    cur.clear();
                    proc_counter.reset();
                }
                ctx.barrier();
                flip = !flip;
            }
            // end of level: both frontiers are empty; reset for next level
            ctx.barrier();
            if ctx.tid == 0 {
                front_a.clear();
                front_b.clear();
                shared.level_count.fetch_add(1, Ordering::Relaxed);
                shared.scanned_edges.fetch_add(m as u64, Ordering::Relaxed);
                metrics.scanned.add(m as u64);
                let live = shared.todo.load(Ordering::Acquire).max(0) as u64;
                metrics.live_edges.set(live as f64);
                let level_secs = sp_level
                    .take()
                    .map(|mut sp| {
                        sp.label("edges", &level_edges.to_string());
                        sp.label("sublevels", &level_subs.to_string());
                        sp.close()
                    })
                    .unwrap_or(0.0);
                shared.levels_ns.fetch_add(secs_to_ns(level_secs), Ordering::Relaxed);
                if level_edges > 0 {
                    shared.per_level.lock().unwrap().push(LevelStat {
                        level: level as u32,
                        edges: level_edges,
                        sublevels: level_subs,
                        secs: level_secs,
                    });
                }
                // compaction check: live must have shrunk (strictly
                // below m, so empty levels never trigger a rebuild loop)
                // and still be nonzero
                if threshold > 0.0
                    && live > 0
                    && (live as usize) < m
                    && (live as f64) < threshold * m as f64
                {
                    want_compact.store(true, Ordering::Release);
                }
                // cancellation checkpoint: same tid-0-decides publish as
                // the compaction request (one Instant read per level)
                if token.should_stop().is_some() {
                    // ORDERING: Release pairs with the Acquire below so
                    // every thread takes the same exit at this boundary.
                    want_stop.store(true, Ordering::Release);
                }
            }
            ctx.barrier();
            level += 1;
            if want_compact.load(Ordering::Acquire) || want_stop.load(Ordering::Acquire) {
                break;
            }
        }
    });
}

#[inline]
fn secs_to_ns(secs: f64) -> u64 {
    (secs * 1e9) as u64
}

/// Process one frontier edge `e1 = <u, v>` (Alg. 5 body): enumerate the
/// surviving triangles through `e1` and decrement the support of their
/// other edges, claiming shared-frontier triangles by the lower-edge-id
/// ownership rule.
#[allow(clippy::too_many_arguments)]
#[inline]
fn process_edge<F: FlagArray>(
    eg: &EdgeGraph,
    g: &crate::graph::Graph,
    e1: EdgeId,
    level: i32,
    s: &[AtomicI32],
    processed: &F,
    in_curr: &F,
    in_next: &F,
    w_next: &mut BatchWriter<'_, EdgeId>,
    x: &mut [u32],
    frozen: Option<&AtomicBitset>,
) {
    let (u, v) = eg.el[e1 as usize];
    // §Perf opt 1: mark the smaller-degree endpoint and scan the larger.
    // Marking costs 2·d(a) (mark + unmark), scanning d(b); the roles of
    // the two discovered edges swap with the endpoints, which is
    // symmetric in the ownership rule below. (A two-pointer sorted-merge
    // variant was tried and reverted: ~2x slower — branchy compares lose
    // to the linear mark/scan; EXPERIMENTS.md §Perf.)
    let (a, b) = if g.degree(u) <= g.degree(v) { (u, v) } else { (v, u) };
    let (alo, ahi) = (g.xadj[a as usize], g.xadj[a as usize + 1]);
    let (blo, bhi) = (g.xadj[b as usize], g.xadj[b as usize + 1]);
    // mark all of N(a) with slot+1
    for j in alo..ahi {
        x[g.adj[j] as usize] = (j - alo) as u32 + 1;
    }
    for j in blo..bhi {
        let w = g.adj[j];
        if w == a {
            continue;
        }
        let xw = x[w as usize];
        if xw == 0 {
            continue;
        }
        let e2 = eg.eid[j]; // <b, w>
        let e3 = eg.eid[alo + xw as usize - 1]; // <a, w>
        if processed.get(e2 as usize) || processed.get(e3 as usize) {
            continue; // triangle already destroyed in an earlier sub-level
        }
        // decrement S[e2] unless e3 (also in curr) owns the triangle
        if !in_curr.get(e3 as usize) || e1 < e3 {
            decrement(e2, level, s, in_next, w_next, frozen);
        }
        // decrement S[e3] unless e2 (also in curr) owns the triangle
        if !in_curr.get(e2 as usize) || e1 < e2 {
            decrement(e3, level, s, in_next, w_next, frozen);
        }
    }
    // unmark
    for j in alo..ahi {
        x[g.adj[j] as usize] = 0;
    }
}

/// Atomically decrement `S[e]` toward `level`, with the paper's
/// overshoot correction (Alg. 5 lines 17–28): the thread that observes
/// the `level+1 → level` transition appends `e` to the next frontier.
/// A frozen edge (region re-peel context, pinned at its known
/// trussness) is never decremented — the pin *is* its final level.
#[inline]
fn decrement<F: FlagArray>(
    e: EdgeId,
    level: i32,
    s: &[AtomicI32],
    in_next: &F,
    w_next: &mut BatchWriter<'_, EdgeId>,
    frozen: Option<&AtomicBitset>,
) {
    let ei = e as usize;
    if frozen.is_some_and(|fz| fz.get(ei)) {
        return;
    }
    if s[ei].load(Ordering::Relaxed) > level {
        let old = s[ei].fetch_sub(1, Ordering::AcqRel);
        if old == level + 1 {
            // this thread completed the transition into the current level
            in_next.set(ei);
            w_next.push(e);
        }
        if old <= level {
            // racing overshoot: another thread got there first — undo
            s[ei].fetch_add(1, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::util::forall;

    fn truss_of(g: crate::graph::Graph, threads: usize) -> Vec<u32> {
        pkt(&EdgeGraph::new(g), &Pool::new(threads)).trussness
    }

    /// The unoptimized reference configuration: no compaction, byte flags.
    const PLAIN: PktConfig = PktConfig { compact_threshold: 0.0, use_bitsets: false };

    #[test]
    fn complete_graph_trussness() {
        // every edge of K_n has trussness n
        for n in [3usize, 4, 5, 7] {
            let t = truss_of(gen::complete(n), 1);
            assert!(t.iter().all(|&x| x as usize == n), "K{n}: {t:?}");
        }
    }

    #[test]
    fn triangle_free_graphs() {
        for g in [gen::ring(12), gen::star(9), gen::grid2d(4, 4)] {
            let t = truss_of(g, 2);
            assert!(t.iter().all(|&x| x == 2), "{t:?}");
        }
    }

    #[test]
    fn paper_figure1_example() {
        // Figure 1 shape: all vertices have coreness 3-ish structure,
        // two edges of trussness 2, the rest trussness 3, and two
        // distinct 3-trusses. Two disjoint triangles joined by two
        // bridge edges reproduce exactly those properties: each bridge
        // lies in no triangle (trussness 2) and each triangle is a
        // maximal 3-truss of its own.
        let g = GraphBuilder::new()
            .edges(&[
                (0, 1), (1, 2), (0, 2), // triangle A
                (3, 4), (4, 5), (3, 5), // triangle B
                (2, 3), (0, 4), // bridges (in no triangle)
            ])
            .build();
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        let hist = super::super::class_histogram(&res.trussness);
        assert_eq!(hist[2], 2, "two bridge edges of trussness 2");
        assert_eq!(hist[3], 6, "six triangle edges of trussness 3");
        assert_eq!(super::super::max_trussness(&res.trussness), 3);
        let trusses = super::super::ktruss_components(&eg, &res.trussness, 3);
        assert_eq!(trusses.len(), 2, "two 3-trusses");
    }

    #[test]
    fn two_triangles_sharing_edge() {
        // bowtie on an edge: vertices 0-1-2 and 1-2-3; shared edge (1,2)
        // has support 2 but peels at level 1: after removing any weaker
        // edge... actual trussness: all edges are in ≥1 triangle;
        // removing nothing — every edge survives the 3-truss (support
        // ≥ 1 within subgraph). 4-truss needs support ≥2: only (1,2) has
        // it, but its triangles die once the others go → all trussness 3.
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
            .build();
        let t = truss_of(g, 1);
        assert!(t.iter().all(|&x| x == 3), "{t:?}");
    }

    #[test]
    fn k5_with_tail() {
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((4, 5)); // pendant edge
        let g = GraphBuilder::new().edges_vec(edges).build();
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        let e_tail = eg.edge_id(4, 5).unwrap() as usize;
        assert_eq!(res.trussness[e_tail], 2);
        for (e, &t) in res.trussness.iter().enumerate() {
            if e != e_tail {
                assert_eq!(t, 5, "edge {e}");
            }
        }
    }

    #[test]
    fn thread_counts_agree() {
        forall("pkt-threads-agree", 10, |rng| {
            let n = rng.range(4, 90);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let t1 = pkt(&eg, &Pool::new(1)).trussness;
            for t in [2, 4, 8] {
                let tp = pkt(&eg, &Pool::new(t)).trussness;
                assert_eq!(t1, tp, "threads={t}");
            }
        });
    }

    #[test]
    fn stats_are_populated() {
        let g = gen::planted_partition(4, 12, 0.8, 0.01, 3);
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        assert!(res.stats.support_secs > 0.0);
        assert!(res.stats.total_secs >= res.stats.support_secs);
        assert!(res.stats.levels > 0);
        assert!(res.stats.levels_secs > 0.0, "level spans recorded");
        assert!(
            res.stats.levels_secs <= res.stats.total_secs,
            "levels nest inside the peel span"
        );
        assert!(res.stats.sublevels >= res.stats.levels as u64 - 1);
        assert!(res.stats.scanned_edges >= eg.m() as u64, "at least one full scan");
        let peeled: u64 = res.stats.per_level.iter().map(|l| l.edges).sum();
        assert_eq!(peeled, eg.m() as u64, "every edge peeled exactly once");
        // per-level trussness histogram must match the result
        let hist = super::super::class_histogram(&res.trussness);
        for ls in &res.stats.per_level {
            assert_eq!(hist[ls.level as usize + 2], ls.edges, "level {}", ls.level);
        }
    }

    #[test]
    fn satisfies_definition() {
        forall("pkt-definition", 6, |rng| {
            let n = rng.range(6, 40);
            let g = gen::planted_partition(2, n / 2, 0.7, 0.1, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let res = pkt(&eg, &Pool::new(3));
            super::super::verify_definition(&eg, &res.trussness).unwrap();
        });
    }

    #[test]
    fn empty_graph() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        let res = pkt(&eg, &Pool::new(2));
        assert!(res.trussness.is_empty());
    }

    #[test]
    fn config_paths_agree_on_known_graph() {
        // K5 + pendant: every (threshold, flags) combination must match
        // the plain path, including the degenerate thresholds
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((4, 5));
        let g = GraphBuilder::new().edges_vec(edges).build();
        let eg = EdgeGraph::new(g);
        let base = pkt_config(&eg, &Pool::new(1), &PLAIN).trussness;
        for thr in [0.0, 0.3, 1.0] {
            for bits in [false, true] {
                let cfg = PktConfig { compact_threshold: thr, use_bitsets: bits };
                let r = pkt_config(&eg, &Pool::new(2), &cfg);
                assert_eq!(r.trussness, base, "thr={thr} bits={bits}");
                if thr == 0.0 {
                    assert_eq!(r.stats.rebuilds, 0, "thr=0 must never rebuild");
                }
            }
        }
    }

    #[test]
    fn compaction_rebuilds_and_reduces_scan_work() {
        // K5 + pendant peels in two waves (tail at level 0, K5 at level
        // 3), so an aggressive threshold must rebuild at least once and
        // scan strictly fewer edges than the m·levels baseline
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((4, 5));
        let g = GraphBuilder::new().edges_vec(edges).build();
        let eg = EdgeGraph::new(g);
        let plain = pkt_config(&eg, &Pool::new(2), &PLAIN);
        let compact = pkt_config(
            &eg,
            &Pool::new(2),
            &PktConfig { compact_threshold: 1.0, use_bitsets: true },
        );
        assert_eq!(plain.trussness, compact.trussness);
        assert!(compact.stats.rebuilds >= 1, "{:?}", compact.stats);
        assert_eq!(plain.stats.rebuilds, 0);
        assert_eq!(
            plain.stats.scanned_edges,
            eg.m() as u64 * plain.stats.levels as u64,
            "without compaction every level scans all of m"
        );
        assert!(
            compact.stats.scanned_edges < plain.stats.scanned_edges,
            "compacted scan work {} must be below baseline {}",
            compact.stats.scanned_edges,
            plain.stats.scanned_edges
        );
        assert_eq!(compact.stats.levels, plain.stats.levels, "same level sequence");
        assert!(compact.stats.compact_secs > 0.0);
    }

    #[test]
    fn cancellation_stops_support_and_peel() {
        let eg = EdgeGraph::new(gen::erdos_renyi(200, 0.2, 5));
        // an expired deadline dies in the support phase (first checkpoint)
        let token = CancelToken::with_timeout(Some(std::time::Duration::ZERO));
        let err =
            pkt_config_with(&eg, &Pool::new(2), &PktConfig::default(), &token).unwrap_err();
        assert_eq!(err.at, "triangle.support");

        // a token cancelled after support stops at the first peel level
        // boundary and reports partial progress
        let s = support_am4_with(&eg, &Pool::new(2), &CancelToken::never()).unwrap();
        let s: Vec<AtomicI32> =
            s.into_iter().map(|a| AtomicI32::new(a.into_inner() as i32)).collect();
        let tok = CancelToken::never();
        tok.cancel();
        let err =
            pkt_with_support_config_with(&eg, &Pool::new(2), s, &PktConfig::default(), &tok)
                .unwrap_err();
        assert_eq!(err.at, "pkt.level");
        assert!(err.partial.contains("levels="), "{}", err.partial);
        assert_eq!(err.reason, crate::par::CancelReason::Cancelled);

        // an inert token agrees with the plain entry point exactly
        let r1 = pkt_config_with(&eg, &Pool::new(2), &PktConfig::default(), &CancelToken::never())
            .unwrap();
        let r2 = pkt(&eg, &Pool::new(2));
        assert_eq!(r1.trussness, r2.trussness);
    }

    #[test]
    fn extreme_thresholds_are_clamped() {
        let eg = EdgeGraph::new(gen::planted_partition(2, 8, 0.9, 0.1, 9));
        let base = pkt_config(&eg, &Pool::new(1), &PLAIN).trussness;
        for thr in [-1.0, 7.5, f64::NAN] {
            let cfg = PktConfig { compact_threshold: thr, use_bitsets: true };
            let r = pkt_config(&eg, &Pool::new(2), &cfg);
            assert_eq!(r.trussness, base, "thr={thr}");
        }
    }
}
