//! PKT — the paper's parallel k-truss decomposition (Alg. 4 + 5).
//!
//! Level-synchronous peeling in "support space": level `l` processes the
//! edges whose remaining support is `l`; their trussness is `l + 2`.
//! Within a level, sub-levels expand the frontier until closure, exactly
//! like ParK does for k-core. Three shared structures carry the state
//! across threads: the atomic support array `S`, the `processed` flags,
//! and the flip-flopped `curr`/`next` frontiers with their `inCurr` /
//! `inNext` membership flags. A triangle whose two unprocessed edges are
//! both in the frontier is claimed by the thread holding the *lower*
//! edge id (the paper's ownership rule, Fig. 3), so every triangle is
//! processed exactly once — the work-efficiency argument of §3.

use crate::graph::{EdgeGraph, EdgeId};
use crate::obs;
use crate::par::{AtomicVec, BatchWriter, Counter, Pool, CHUNK_PROCESS};
use crate::triangle::support_am4;
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicI64, AtomicU64, Ordering};

/// Per-level timing/size record (drives Fig. 6).
#[derive(Clone, Debug)]
pub struct LevelStat {
    /// Support level `l`; the edges peeled here have trussness `l + 2`.
    pub level: u32,
    /// Edges peeled at this level.
    pub edges: u64,
    /// Sub-levels needed to close the level.
    pub sublevels: u32,
    /// Wall time spent processing this level (scan + all sub-levels).
    pub secs: f64,
}

/// Phase breakdown and level statistics for one PKT run (Figs. 4–6).
///
/// Every duration here is derived from `obs` spans (`pkt.support`,
/// `pkt.peel`, `pkt.scan`, `pkt.process`, `pkt.level`), so the struct
/// always agrees with what the registry histograms and the trace sink
/// record for the same run.
#[derive(Clone, Debug, Default)]
pub struct PktStats {
    pub support_secs: f64,
    pub scan_secs: f64,
    pub process_secs: f64,
    /// Sum of all `pkt.level` span durations, including levels that
    /// peeled nothing (unlike `per_level`, which keeps only non-empty
    /// levels for Fig. 6).
    pub levels_secs: f64,
    pub total_secs: f64,
    pub levels: u32,
    pub sublevels: u64,
    pub per_level: Vec<LevelStat>,
}

/// Result of a truss decomposition run.
#[derive(Clone, Debug)]
pub struct TrussResult {
    /// Trussness per edge id (`S[e] + 2` in the paper's convention).
    pub trussness: Vec<u32>,
    pub stats: PktStats,
}

/// Run PKT: AM4 support computation followed by level-synchronous
/// parallel peeling.
pub fn pkt(eg: &EdgeGraph, pool: &Pool) -> TrussResult {
    let sp = obs::span("pkt.support");
    let s_u32 = support_am4(eg, pool);
    let support_secs = sp.close();
    let s: Vec<AtomicI32> = s_u32
        .into_iter()
        .map(|a| AtomicI32::new(a.into_inner() as i32))
        .collect();
    let mut res = pkt_with_support(eg, pool, s);
    res.stats.support_secs = support_secs;
    res.stats.total_secs += support_secs;
    res
}

/// The peeling phase of PKT, given a precomputed atomic support array.
/// Exposed separately so benches can ablate the support method (AM4 vs
/// Ros) inside the same peel.
pub fn pkt_with_support(eg: &EdgeGraph, pool: &Pool, s: Vec<AtomicI32>) -> TrussResult {
    let n = eg.n();
    let m = eg.m();
    let g = &eg.g;
    let sp_peel = obs::span("pkt.peel");

    let processed: Vec<AtomicBool> = (0..m).map(|_| AtomicBool::new(false)).collect();
    // membership flags for the two flip-flopped frontiers
    let in_a: Vec<AtomicBool> = (0..m).map(|_| AtomicBool::new(false)).collect();
    let in_b: Vec<AtomicBool> = (0..m).map(|_| AtomicBool::new(false)).collect();
    let front_a: AtomicVec<EdgeId> = AtomicVec::with_capacity(m);
    let front_b: AtomicVec<EdgeId> = AtomicVec::with_capacity(m);

    let todo = AtomicI64::new(m as i64);
    let proc_counter = Counter::new();
    // phase accumulators (nanoseconds), fed from tid-0 spans between
    // barriers; the same spans drive the registry histograms and trace
    let scan_ns = AtomicU64::new(0);
    let process_ns = AtomicU64::new(0);
    let levels_ns = AtomicU64::new(0);
    let sublevel_count = AtomicU64::new(0);
    let level_count = AtomicU64::new(0);
    let per_level = std::sync::Mutex::new(Vec::<LevelStat>::new());

    pool.region(|ctx| {
        let mut x = vec![0u32; n]; // thread-local marking array (u32 slots: cache-friendlier)
        let mut level: i32 = 0;
        while todo.load(Ordering::Acquire) > 0 {
            let mut sp_level: Option<obs::Span> = None;
            let mut sp_scan: Option<obs::Span> = None;
            if ctx.tid == 0 {
                let lvl = level.to_string();
                sp_level = Some(obs::span_with("pkt.level", &[("level", &lvl)]));
                sp_scan = Some(obs::span("pkt.scan"));
            }
            // ---- SCAN: static schedule over S (paper §4.1) ----
            {
                let mut w = BatchWriter::new(&front_a);
                let (lo, hi) = ctx.static_range(m);
                for e in lo..hi {
                    if !processed[e].load(Ordering::Relaxed)
                        && s[e].load(Ordering::Relaxed) == level
                    {
                        in_a[e].store(true, Ordering::Relaxed);
                        w.push(e as EdgeId);
                    }
                }
            }
            ctx.barrier();
            if let Some(sp) = sp_scan {
                scan_ns.fetch_add(secs_to_ns(sp.close()), Ordering::Relaxed);
            }

            // ---- sub-level expansion ----
            let mut flip = false;
            let mut level_edges = 0u64;
            let mut level_subs = 0u32;
            loop {
                let (cur, cur_in, nxt, nxt_in) = if !flip {
                    (&front_a, &in_a, &front_b, &in_b)
                } else {
                    (&front_b, &in_b, &front_a, &in_a)
                };
                let cur_len = cur.len();
                if cur_len == 0 {
                    break;
                }
                level_edges += cur_len as u64;
                level_subs += 1;
                if ctx.tid == 0 {
                    todo.fetch_sub(cur_len as i64, Ordering::AcqRel);
                    sublevel_count.fetch_add(1, Ordering::Relaxed);
                }
                let sp_proc = if ctx.tid == 0 { Some(obs::span("pkt.process")) } else { None };
                {
                    let cur_slice = cur.as_slice();
                    let mut w = BatchWriter::new(nxt);
                    ctx.for_dynamic(&proc_counter, cur_len, CHUNK_PROCESS, |i| {
                        let e1 = cur_slice[i];
                        process_edge(
                            eg, g, e1, level, &s, &processed, cur_in, nxt_in, &mut w,
                            &mut x,
                        );
                    });
                }
                ctx.barrier();
                if let Some(sp) = sp_proc {
                    process_ns.fetch_add(secs_to_ns(sp.close()), Ordering::Relaxed);
                }
                // retire the current frontier: mark processed, clear flags
                {
                    let cur_slice = cur.as_slice();
                    ctx.for_static(cur_len, |i| {
                        let e = cur_slice[i] as usize;
                        processed[e].store(true, Ordering::Relaxed);
                        cur_in[e].store(false, Ordering::Relaxed);
                    });
                }
                ctx.barrier();
                if ctx.tid == 0 {
                    cur.clear();
                    proc_counter.reset();
                }
                ctx.barrier();
                flip = !flip;
            }
            // end of level: both frontiers are empty; reset for next level
            ctx.barrier();
            if ctx.tid == 0 {
                front_a.clear();
                front_b.clear();
                level_count.fetch_add(1, Ordering::Relaxed);
                let level_secs = sp_level
                    .take()
                    .map(|mut sp| {
                        sp.label("edges", &level_edges.to_string());
                        sp.label("sublevels", &level_subs.to_string());
                        sp.close()
                    })
                    .unwrap_or(0.0);
                levels_ns.fetch_add(secs_to_ns(level_secs), Ordering::Relaxed);
                if level_edges > 0 {
                    per_level.lock().unwrap().push(LevelStat {
                        level: level as u32,
                        edges: level_edges,
                        sublevels: level_subs,
                        secs: level_secs,
                    });
                }
            }
            ctx.barrier();
            level += 1;
        }
    });

    let trussness: Vec<u32> = s
        .iter()
        .map(|a| (a.load(Ordering::Relaxed) + 2) as u32)
        .collect();
    let total_secs = sp_peel.close();
    let stats = PktStats {
        support_secs: 0.0,
        scan_secs: scan_ns.into_inner() as f64 * 1e-9,
        process_secs: process_ns.into_inner() as f64 * 1e-9,
        levels_secs: levels_ns.into_inner() as f64 * 1e-9,
        total_secs,
        levels: level_count.into_inner() as u32,
        sublevels: sublevel_count.into_inner(),
        per_level: per_level.into_inner().unwrap(),
    };
    TrussResult { trussness, stats }
}

#[inline]
fn secs_to_ns(secs: f64) -> u64 {
    (secs * 1e9) as u64
}

/// Process one frontier edge `e1 = <u, v>` (Alg. 5 body): enumerate the
/// surviving triangles through `e1` and decrement the support of their
/// other edges, claiming shared-frontier triangles by the lower-edge-id
/// ownership rule.
#[allow(clippy::too_many_arguments)]
#[inline]
fn process_edge(
    eg: &EdgeGraph,
    g: &crate::graph::Graph,
    e1: EdgeId,
    level: i32,
    s: &[AtomicI32],
    processed: &[AtomicBool],
    in_curr: &[AtomicBool],
    in_next: &[AtomicBool],
    w_next: &mut BatchWriter<'_, EdgeId>,
    x: &mut [u32],
) {
    let (u, v) = eg.el[e1 as usize];
    // §Perf opt 1: mark the smaller-degree endpoint and scan the larger.
    // Marking costs 2·d(a) (mark + unmark), scanning d(b); the roles of
    // the two discovered edges swap with the endpoints, which is
    // symmetric in the ownership rule below. (A two-pointer sorted-merge
    // variant was tried and reverted: ~2x slower — branchy compares lose
    // to the linear mark/scan; EXPERIMENTS.md §Perf.)
    let (a, b) = if g.degree(u) <= g.degree(v) { (u, v) } else { (v, u) };
    let (alo, ahi) = (g.xadj[a as usize], g.xadj[a as usize + 1]);
    let (blo, bhi) = (g.xadj[b as usize], g.xadj[b as usize + 1]);
    // mark all of N(a) with slot+1
    for j in alo..ahi {
        x[g.adj[j] as usize] = (j - alo) as u32 + 1;
    }
    for j in blo..bhi {
        let w = g.adj[j];
        if w == a {
            continue;
        }
        let xw = x[w as usize];
        if xw == 0 {
            continue;
        }
        let e2 = eg.eid[j]; // <b, w>
        let e3 = eg.eid[alo + xw as usize - 1]; // <a, w>
        if processed[e2 as usize].load(Ordering::Relaxed)
            || processed[e3 as usize].load(Ordering::Relaxed)
        {
            continue; // triangle already destroyed in an earlier sub-level
        }
        // decrement S[e2] unless e3 (also in curr) owns the triangle
        if !in_curr[e3 as usize].load(Ordering::Relaxed) || e1 < e3 {
            decrement(e2, level, s, in_next, w_next);
        }
        // decrement S[e3] unless e2 (also in curr) owns the triangle
        if !in_curr[e2 as usize].load(Ordering::Relaxed) || e1 < e2 {
            decrement(e3, level, s, in_next, w_next);
        }
    }
    // unmark
    for j in alo..ahi {
        x[g.adj[j] as usize] = 0;
    }
}

/// Atomically decrement `S[e]` toward `level`, with the paper's
/// overshoot correction (Alg. 5 lines 17–28): the thread that observes
/// the `level+1 → level` transition appends `e` to the next frontier.
#[inline]
fn decrement(
    e: EdgeId,
    level: i32,
    s: &[AtomicI32],
    in_next: &[AtomicBool],
    w_next: &mut BatchWriter<'_, EdgeId>,
) {
    let ei = e as usize;
    if s[ei].load(Ordering::Relaxed) > level {
        let old = s[ei].fetch_sub(1, Ordering::AcqRel);
        if old == level + 1 {
            // this thread completed the transition into the current level
            in_next[ei].store(true, Ordering::Relaxed);
            w_next.push(e);
        }
        if old <= level {
            // racing overshoot: another thread got there first — undo
            s[ei].fetch_add(1, Ordering::AcqRel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::util::forall;

    fn truss_of(g: crate::graph::Graph, threads: usize) -> Vec<u32> {
        pkt(&EdgeGraph::new(g), &Pool::new(threads)).trussness
    }

    #[test]
    fn complete_graph_trussness() {
        // every edge of K_n has trussness n
        for n in [3usize, 4, 5, 7] {
            let t = truss_of(gen::complete(n), 1);
            assert!(t.iter().all(|&x| x as usize == n), "K{n}: {t:?}");
        }
    }

    #[test]
    fn triangle_free_graphs() {
        for g in [gen::ring(12), gen::star(9), gen::grid2d(4, 4)] {
            let t = truss_of(g, 2);
            assert!(t.iter().all(|&x| x == 2), "{t:?}");
        }
    }

    #[test]
    fn paper_figure1_example() {
        // Figure 1: 8-vertex graph; all coreness 3, two edges trussness 2,
        // rest trussness 3, two 3-trusses. Two K4-minus-one-edge blocks
        // joined by two bridge edges reproduce those properties: use two
        // "diamond" blocks (K4 minus an edge gives trussness-3 edges? no:
        // K4\e edges lie in ≤1 triangle each → trussness 3 only for the
        // middle edge... ). Use instead: two K4s (每 edge trussness 4? K4
        // edges have 2 triangles → trussness 4)… Figure 1 has trussness-3
        // edges, i.e. blocks where each edge is in exactly 1 surviving
        // triangle: triangles sharing nothing. Simplest faithful instance:
        // two disjoint triangles plus two bridge edges between them.
        let g = GraphBuilder::new()
            .edges(&[
                (0, 1), (1, 2), (0, 2), // triangle A
                (3, 4), (4, 5), (3, 5), // triangle B
                (2, 3), (0, 4), // bridges (in no triangle)
            ])
            .build();
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        let hist = super::super::class_histogram(&res.trussness);
        assert_eq!(hist[2], 2, "two bridge edges of trussness 2");
        assert_eq!(hist[3], 6, "six triangle edges of trussness 3");
        assert_eq!(super::super::max_trussness(&res.trussness), 3);
        let trusses = super::super::ktruss_components(&eg, &res.trussness, 3);
        assert_eq!(trusses.len(), 2, "two 3-trusses");
    }

    #[test]
    fn two_triangles_sharing_edge() {
        // bowtie on an edge: vertices 0-1-2 and 1-2-3; shared edge (1,2)
        // has support 2 but peels at level 1: after removing any weaker
        // edge... actual trussness: all edges are in ≥1 triangle;
        // removing nothing — every edge survives the 3-truss (support
        // ≥ 1 within subgraph). 4-truss needs support ≥2: only (1,2) has
        // it, but its triangles die once the others go → all trussness 3.
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
            .build();
        let t = truss_of(g, 1);
        assert!(t.iter().all(|&x| x == 3), "{t:?}");
    }

    #[test]
    fn k5_with_tail() {
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((4, 5)); // pendant edge
        let g = GraphBuilder::new().edges_vec(edges).build();
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        let e_tail = eg.edge_id(4, 5).unwrap() as usize;
        assert_eq!(res.trussness[e_tail], 2);
        for (e, &t) in res.trussness.iter().enumerate() {
            if e != e_tail {
                assert_eq!(t, 5, "edge {e}");
            }
        }
    }

    #[test]
    fn thread_counts_agree() {
        forall("pkt-threads-agree", 10, |rng| {
            let n = rng.range(4, 90);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let t1 = pkt(&eg, &Pool::new(1)).trussness;
            for t in [2, 4, 8] {
                let tp = pkt(&eg, &Pool::new(t)).trussness;
                assert_eq!(t1, tp, "threads={t}");
            }
        });
    }

    #[test]
    fn stats_are_populated() {
        let g = gen::planted_partition(4, 12, 0.8, 0.01, 3);
        let eg = EdgeGraph::new(g);
        let res = pkt(&eg, &Pool::new(2));
        assert!(res.stats.support_secs > 0.0);
        assert!(res.stats.total_secs >= res.stats.support_secs);
        assert!(res.stats.levels > 0);
        assert!(res.stats.levels_secs > 0.0, "level spans recorded");
        assert!(
            res.stats.levels_secs <= res.stats.total_secs,
            "levels nest inside the peel span"
        );
        assert!(res.stats.sublevels >= res.stats.levels as u64 - 1);
        let peeled: u64 = res.stats.per_level.iter().map(|l| l.edges).sum();
        assert_eq!(peeled, eg.m() as u64, "every edge peeled exactly once");
        // per-level trussness histogram must match the result
        let hist = super::super::class_histogram(&res.trussness);
        for ls in &res.stats.per_level {
            assert_eq!(hist[ls.level as usize + 2], ls.edges, "level {}", ls.level);
        }
    }

    #[test]
    fn satisfies_definition() {
        forall("pkt-definition", 6, |rng| {
            let n = rng.range(6, 40);
            let g = gen::planted_partition(2, n / 2, 0.7, 0.1, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let res = pkt(&eg, &Pool::new(3));
            super::super::verify_definition(&eg, &res.trussness).unwrap();
        });
    }

    #[test]
    fn empty_graph() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        let res = pkt(&eg, &Pool::new(2));
        assert!(res.trussness.is_empty());
    }
}
