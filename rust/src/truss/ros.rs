//! Ros — Rossi's truss decomposition (PAKDD 2014), as characterized in
//! §2 of the paper: only the support-computation phase is parallel
//! (Alg. 2, edge-based, orientation-oblivious); the peel itself is the
//! serial ascending-support sweep, but over the hash-free edge-id
//! representation (Fig. 2) rather than WC's hash table.

use crate::graph::{EdgeGraph, EdgeId};
use crate::par::Pool;
use crate::triangle::support_ros;
use crate::truss::{PktStats, TrussResult};
use std::time::Instant;

/// Run Ros: parallel support (Alg. 2) + serial hash-free peeling.
pub fn ros(eg: &EdgeGraph, pool: &Pool) -> TrussResult {
    let t0 = Instant::now();
    let g = &eg.g;
    let n = eg.n();
    let m = eg.m();

    let mut s = support_ros(eg, pool);
    let support_secs = t0.elapsed().as_secs_f64();

    // counting-sort bucket structure (same as WC)
    let smax = s.iter().copied().max().unwrap_or(0) as usize;
    let mut bin = vec![0usize; smax + 2];
    for &x in &s {
        bin[x as usize + 1] += 1;
    }
    for d in 0..=smax {
        bin[d + 1] += bin[d];
    }
    let mut vert = vec![0 as EdgeId; m];
    let mut pos = vec![0usize; m];
    {
        let mut cursor = bin.clone();
        for e in 0..m {
            let d = s[e] as usize;
            pos[e] = cursor[d];
            vert[pos[e]] = e as EdgeId;
            cursor[d] += 1;
        }
    }

    let mut processed = vec![false; m];
    let mut x = vec![0usize; n]; // marking array, replaces the hash table

    for i in 0..m {
        let e = vert[i] as usize;
        let k = s[e];
        let (u, v) = eg.el[e];
        // mark N(u) with slot+1
        let (ulo, uhi) = (g.xadj[u as usize], g.xadj[u as usize + 1]);
        for j in ulo..uhi {
            x[g.adj[j] as usize] = j + 1;
        }
        let (vlo, vhi) = (g.xadj[v as usize], g.xadj[v as usize + 1]);
        for j in vlo..vhi {
            let w = g.adj[j];
            if w == u {
                continue;
            }
            let xw = x[w as usize];
            if xw == 0 {
                continue;
            }
            let e2 = eg.eid[j] as usize; // <v, w>
            let e3 = eg.eid[xw - 1] as usize; // <u, w>
            if processed[e2] || processed[e3] {
                continue;
            }
            for f in [e2, e3] {
                if s[f] > k {
                    let sf = s[f] as usize;
                    let pf = pos[f];
                    let pw = bin[sf];
                    let w2 = vert[pw] as usize;
                    if f != w2 {
                        vert.swap(pf, pw);
                        pos[f] = pw;
                        pos[w2] = pf;
                    }
                    bin[sf] += 1;
                    s[f] -= 1;
                }
            }
        }
        for j in ulo..uhi {
            x[g.adj[j] as usize] = 0;
        }
        processed[e] = true;
    }

    let total = t0.elapsed().as_secs_f64();
    TrussResult {
        trussness: s.iter().map(|&x| x + 2).collect(),
        stats: PktStats {
            support_secs,
            process_secs: total - support_secs,
            total_secs: total,
            ..Default::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::truss::{pkt, wc};
    use crate::util::forall;

    #[test]
    fn ros_complete_graph() {
        let eg = EdgeGraph::new(gen::complete(6));
        let t = ros(&eg, &Pool::new(2)).trussness;
        assert!(t.iter().all(|&x| x == 6));
    }

    #[test]
    fn ros_matches_pkt_and_wc() {
        forall("ros-eq-all", 12, |rng| {
            let n = rng.range(4, 70);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let r = ros(&eg, &Pool::new(2)).trussness;
            assert_eq!(r, pkt(&eg, &Pool::new(2)).trussness);
            assert_eq!(r, wc(&eg).trussness);
        });
    }

    #[test]
    fn ros_clustered_graph() {
        let g = gen::planted_partition(3, 16, 0.8, 0.02, 4);
        let eg = EdgeGraph::new(g);
        assert_eq!(
            ros(&eg, &Pool::new(4)).trussness,
            pkt(&eg, &Pool::new(4)).trussness
        );
    }

    #[test]
    fn ros_empty() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        assert!(ros(&eg, &Pool::new(1)).trussness.is_empty());
    }
}
