//! Dense-block truss decomposition through the AOT XLA artifacts — the
//! Graphulo-style linear-algebra formulation (paper ref [20]) the stack's
//! L1/L2 layers implement: support is `S = (A·A) ⊙ A` (a Pallas tiled
//! masked matmul), peeling zeroes edges below threshold.
//!
//! Used two ways:
//! 1. an **independent correctness oracle** for PKT (different algorithm,
//!    different layer, different numerics path);
//! 2. a dense-subgraph support backend for graphs that fit one block.
//!
//! Python never runs here: the HLO was lowered once by `make artifacts`.

use crate::graph::EdgeGraph;
use crate::runtime::{literal_matrix, literal_scalar, Manifest, Runtime};
use anyhow::{bail, Context, Result};

/// Dense XLA backend bound to one block size `B` (graph must satisfy
/// n ≤ B).
pub struct DenseBackend<'rt> {
    rt: &'rt Runtime,
    pub block: usize,
}

impl<'rt> DenseBackend<'rt> {
    /// Pick the smallest available block ≥ n from the manifest.
    pub fn for_graph(rt: &'rt Runtime, manifest: &Manifest, n: usize) -> Result<Self> {
        let block = manifest
            .support_blocks()
            .into_iter()
            .find(|&b| b >= n)
            .with_context(|| {
                format!(
                    "no artifact block >= n={n} (available: {:?})",
                    manifest.support_blocks()
                )
            })?;
        if !manifest.has(&format!("peel_{block}")) {
            bail!("manifest has support_{block} but no peel_{block}");
        }
        Ok(Self { rt, block })
    }

    /// Explicit block size (must be loaded in the runtime).
    pub fn with_block(rt: &'rt Runtime, block: usize) -> Self {
        Self { rt, block }
    }

    /// Dense symmetric 0/1 adjacency, padded to B×B.
    fn dense_adjacency(&self, eg: &EdgeGraph) -> Result<Vec<f32>> {
        let b = self.block;
        if eg.n() > b {
            bail!("graph n={} exceeds dense block {b}", eg.n());
        }
        let mut a = vec![0f32; b * b];
        for &(u, v) in &eg.el {
            a[u as usize * b + v as usize] = 1.0;
            a[v as usize * b + u as usize] = 1.0;
        }
        Ok(a)
    }

    /// Edge-support via the `support_B` artifact: one XLA call computing
    /// `S = (A·A) ⊙ A`; the (u,v) entry is the triangle count of <u,v>.
    pub fn support(&self, eg: &EdgeGraph) -> Result<Vec<u32>> {
        let b = self.block;
        let a = self.dense_adjacency(eg)?;
        let name = format!("support_{b}");
        let out = self
            .rt
            .execute_f32(&name, &[literal_matrix(&a, b, b)?])?;
        let s = &out[0];
        Ok(eg
            .el
            .iter()
            .map(|&(u, v)| s[u as usize * b + v as usize].round() as u32)
            .collect())
    }

    /// Full truss decomposition by iterated XLA peeling. Edges that
    /// disappear at threshold `k−1` have trussness exactly `k`.
    ///
    /// Two modes (EXPERIMENTS.md §Perf): with a `peelfix_B` artifact the
    /// per-k fixpoint runs **in-device** (`lax.while_loop` in the L2
    /// model — one PJRT call per k); otherwise each inner step is one
    /// `peel_B` call (`A' = A ⊙ [(A·A) ⊙ A ≥ thresh]`).
    pub fn decompose(&self, eg: &EdgeGraph) -> Result<Vec<u32>> {
        let b = self.block;
        let m = eg.m();
        let mut a = self.dense_adjacency(eg)?;
        let mut truss = vec![2u32; m];
        let mut live = m;
        let mut k = 2u32;
        let peel = format!("peel_{b}");
        let peelfix = format!("peelfix_{b}");
        let fused = self.rt.has(&peelfix);
        // safety valve: trussness is bounded by n, and every outer round
        // with no removals advances k, so ≤ n + t_max iterations total.
        let max_iters = 4 * (b + m + 4);
        let mut iters = 0usize;
        while live > 0 {
            loop {
                iters += 1;
                if iters > max_iters {
                    bail!("dense peel failed to converge (iters > {max_iters})");
                }
                let name = if fused { &peelfix } else { &peel };
                let out = self.rt.execute_f32(
                    name,
                    &[literal_matrix(&a, b, b)?, literal_scalar((k - 1) as f32)],
                )?;
                let a_new = &out[0];
                let mut removed = 0usize;
                for (e, &(u, v)) in eg.el.iter().enumerate() {
                    let idx = u as usize * b + v as usize;
                    if a[idx] != 0.0 && a_new[idx] == 0.0 {
                        truss[e] = k;
                        removed += 1;
                    }
                }
                if removed == 0 {
                    break;
                }
                live -= removed;
                a.copy_from_slice(a_new);
                // the fused program already reached the per-k fixpoint
                if live == 0 || fused {
                    break;
                }
            }
            k += 1;
        }
        Ok(truss)
    }
}

// NOTE: tests for this module live in rust/tests/xla_integration.rs —
// they need `make artifacts` to have produced the HLO files first.
