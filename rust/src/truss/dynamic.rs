//! Batch-dynamic truss maintenance: keep a full PKT decomposition
//! up to date under edge insertions and deletions without recomputing
//! from scratch (Jakkula–Karypis streaming/batch truss maintenance,
//! with the triangle-locality bounds of Wang–Cheng).
//!
//! [`DynamicTruss`] owns the CSR ([`EdgeGraph`]), the per-edge support
//! and the per-edge trussness of the current graph. A batch update runs
//! in four phases:
//!
//! 1. **normalize + rebuild** — canonicalize the batch (u < v, drop
//!    self-loops and duplicates, skip already-present inserts /
//!    already-absent removes) and rebuild the CSR with the surviving
//!    edits; old trussness rides across by a linear merge of the two
//!    lexicographic edge lists.
//! 2. **affected region** — a BFS over *triangle adjacency* (two edges
//!    are adjacent iff they close a triangle) from the touched edges.
//!    The cascade lemma bounds it: an edge's trussness changes only if
//!    it shares a triangle with an edited edge or with another changed
//!    edge, so the BFS expands only through change candidates. Two
//!    pruning rules cut candidates provably unaffected:
//!    - *delete*: an edge with `t > max t(deleted)` keeps its old-graph
//!      k-truss intact (no deleted edge was in it), so it cannot drop;
//!    - *insert*: a changed edge ends in a k-truss through an inserted
//!      edge `d`, so `k ≤ supp(d) + 2`; anything already at or above
//!      `K = max_d supp(d) + 2` cannot rise.
//!    Pruned neighbors of the region become frozen *context*.
//! 3. **region re-peel** — the affected + context edges are compacted
//!    into a sub-[`EdgeGraph`] ([`compact_edges`]); affected supports
//!    are recounted there (all their triangles are inside the region by
//!    construction), context edges are pinned at `t - 2` and marked in
//!    a frozen [`AtomicBitset`] the peel never decrements. The standard
//!    staged `peel_driver` then replays the peel: context edges enter
//!    the frontier at their known level and exert exactly the influence
//!    they have in a full peel.
//! 4. **write-back** — new trussness for affected edges, incremental
//!    support deltas (one per created/destroyed triangle, claimed by
//!    the lowest touched edge id so shared triangles count once), and
//!    an [`UpdateReport`] delta summary.
//!
//! Every update runs under a `dynamic.insert` / `dynamic.remove` obs
//! span and bumps `dynamic_updates_total{op=..}` and
//! `dynamic_affected_edges_total`. With [`crate::validate`] enabled the
//! maintained state is checked against a from-scratch recompute
//! ([`crate::validate::check_dynamic`]) after every batch.

use super::pkt::{pkt_region_peel, pkt_with_support_config_with, PktConfig};
use crate::graph::{compact_edges, EdgeGraph, EdgeId, Graph, GraphBuilder, Vertex};
use crate::obs;
use crate::par::cancel::{CancelToken, Cancelled};
use crate::par::sync::atomic::{AtomicI32, Ordering};
use crate::par::{AtomicBitset, Pool};
use crate::triangle::support_am4_with;
use std::collections::VecDeque;
use std::sync::OnceLock;

/// Which way a batch moved the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOp {
    Insert,
    Remove,
}

impl UpdateOp {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Insert => "insert",
            Self::Remove => "remove",
        }
    }
}

/// Delta report of one batch update (the server's `OK` line and the
/// CLI's per-batch output both render [`UpdateReport::summary`]).
#[derive(Clone, Debug)]
pub struct UpdateReport {
    pub op: UpdateOp,
    /// Raw batch size as submitted.
    pub requested: usize,
    /// Edges actually inserted/removed after normalization.
    pub applied: usize,
    /// Duplicates, self-loops, already-present (insert) or
    /// already-absent (remove) entries.
    pub skipped: usize,
    /// Edges whose trussness was recomputed (the affected region).
    pub affected: usize,
    /// Frozen boundary edges pinned at their known trussness.
    pub context: usize,
    /// Edges whose trussness actually changed (applied edges included).
    pub changed: usize,
    /// Peel levels re-run over the region (0 when nothing re-peeled).
    pub levels: u32,
    /// Maximum trussness after the update.
    pub t_max: u32,
    pub n: usize,
    pub m: usize,
    pub secs: f64,
}

impl UpdateReport {
    pub fn summary(&self) -> String {
        format!(
            "op={} requested={} applied={} skipped={} affected={} context={} \
             changed={} levels={} tmax={} n={} m={} secs={:.6}",
            self.op.name(),
            self.requested,
            self.applied,
            self.skipped,
            self.affected,
            self.context,
            self.changed,
            self.levels,
            self.t_max,
            self.n,
            self.m,
            self.secs
        )
    }
}

/// Cached registry handles (same pattern as `pkt_obs`).
struct DynObs {
    inserts: obs::Counter,
    removes: obs::Counter,
    affected: obs::Counter,
}

fn dyn_obs() -> &'static DynObs {
    static OBS: OnceLock<DynObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = obs::global();
        DynObs {
            inserts: r.counter("dynamic_updates_total", &[("op", "insert")]),
            removes: r.counter("dynamic_updates_total", &[("op", "remove")]),
            affected: r.counter("dynamic_affected_edges_total", &[]),
        }
    })
}

/// BFS edge states over the *new* graph's edge ids.
const UNSEEN: u8 = 0;
/// In the affected region: trussness is recomputed by the region peel.
const AFFECTED: u8 = 1;
/// Region boundary: present in the re-peel, pinned at old trussness.
const CONTEXT: u8 = 2;

/// Poll the cancel token every this many BFS expansions.
const BFS_POLL: usize = 4096;

/// A truss decomposition that stays correct under batch edge updates.
pub struct DynamicTruss {
    eg: EdgeGraph,
    support: Vec<u32>,
    trussness: Vec<u32>,
    cfg: PktConfig,
    threads: usize,
}

impl DynamicTruss {
    /// Full PKT run with default tuning; the result seeds the
    /// maintained state.
    pub fn new(g: Graph, threads: usize) -> Self {
        Self::with_config(g, threads, PktConfig::default())
    }

    /// [`DynamicTruss::new`] with explicit peel tuning (the same knobs
    /// apply to the initial run and every region re-peel).
    pub fn with_config(g: Graph, threads: usize, cfg: PktConfig) -> Self {
        match Self::with_config_token(g, threads, cfg, &CancelToken::never()) {
            Ok(s) => s,
            // a never-token cannot stop the initial decomposition
            Err(c) => unreachable!("dynamic init cancelled without a token: {c}"),
        }
    }

    /// Cancellable construction: the token is polled at the usual
    /// support/peel boundaries of the initial full run.
    pub fn with_config_token(
        g: Graph,
        threads: usize,
        cfg: PktConfig,
        token: &CancelToken,
    ) -> Result<Self, Cancelled> {
        let eg = EdgeGraph::new(g);
        let pool = Pool::new(threads);
        let sp = obs::span("pkt.support");
        let sup = support_am4_with(&eg, &pool, token)?;
        sp.close();
        let support: Vec<u32> = sup.into_iter().map(|a| a.into_inner()).collect();
        let s: Vec<AtomicI32> =
            support.iter().map(|&v| AtomicI32::new(v as i32)).collect();
        let res = pkt_with_support_config_with(&eg, &pool, s, &cfg, token)?;
        Ok(Self { eg, support, trussness: res.trussness, cfg, threads })
    }

    pub fn eg(&self) -> &EdgeGraph {
        &self.eg
    }

    pub fn graph(&self) -> &Graph {
        &self.eg.g
    }

    /// Maintained trussness per edge id of the *current* graph.
    pub fn trussness(&self) -> &[u32] {
        &self.trussness
    }

    /// Maintained triangle support per edge id of the current graph.
    pub fn support(&self) -> &[u32] {
        &self.support
    }

    pub fn t_max(&self) -> u32 {
        super::max_trussness(&self.trussness)
    }

    pub fn n(&self) -> usize {
        self.eg.n()
    }

    pub fn m(&self) -> usize {
        self.eg.m()
    }

    /// Insert a batch of edges. Self-loops, duplicates and
    /// already-present edges are skipped (counted in the report).
    pub fn insert_batch(&mut self, batch: &[(Vertex, Vertex)]) -> UpdateReport {
        match self.insert_batch_with(batch, &CancelToken::never()) {
            Ok(r) => r,
            Err(c) => unreachable!("insert cancelled without a token: {c}"),
        }
    }

    /// [`DynamicTruss::insert_batch`] with cooperative cancellation. On
    /// `Err` the maintained state is unchanged (all mutation happens in
    /// a final write-back after the region peel succeeds).
    pub fn insert_batch_with(
        &mut self,
        batch: &[(Vertex, Vertex)],
        token: &CancelToken,
    ) -> Result<UpdateReport, Cancelled> {
        let nb = batch.len().to_string();
        let sp = obs::span_with("dynamic.insert", &[("batch", &nb)]);
        dyn_obs().inserts.inc();
        if token.should_stop().is_some() {
            return Err(token.stopped("dynamic.insert", "before batch".into()));
        }

        // -- normalize: canonical, deduplicated, not already present --
        let mut add: Vec<(Vertex, Vertex)> = Vec::with_capacity(batch.len());
        let old_n = self.eg.n() as Vertex;
        for &(a, b) in batch {
            if a == b {
                continue;
            }
            let (u, v) = if a < b { (a, b) } else { (b, a) };
            // endpoints beyond the current vertex set are new vertices
            if v < old_n && self.eg.edge_id(u, v).is_some() {
                continue;
            }
            add.push((u, v));
        }
        add.sort_unstable();
        add.dedup();
        if add.is_empty() {
            return Ok(self.noop_report(UpdateOp::Insert, batch.len(), sp.close()));
        }
        let applied = add.len();

        // -- rebuild the CSR with the new edges --
        let sp_build = obs::span("dynamic.rebuild");
        let mut edges: Vec<(Vertex, Vertex)> =
            Vec::with_capacity(self.eg.m() + applied);
        edges.extend_from_slice(&self.eg.el);
        edges.extend_from_slice(&add);
        let new_g =
            GraphBuilder::new().num_vertices(self.eg.n()).edges_vec(edges).build();
        let new_eg = EdgeGraph::new(new_g);
        sp_build.close();
        let m_new = new_eg.m();

        // -- carry old state across (both edge lists are lexicographic,
        // and the new list is a strict superset: one linear merge) --
        let mut t_prev = vec![0u32; m_new];
        let mut sup = vec![0u32; m_new];
        let mut inserted = vec![false; m_new];
        let mut oi = 0usize;
        for (e, &uv) in new_eg.el.iter().enumerate() {
            if oi < self.eg.m() && self.eg.el[oi] == uv {
                t_prev[e] = self.trussness[oi];
                sup[e] = self.support[oi];
                oi += 1;
            } else {
                inserted[e] = true;
            }
        }
        debug_assert_eq!(oi, self.eg.m(), "every old edge survives an insert");

        // -- incremental support: each triangle through an inserted edge
        // is new; the lowest inserted edge id in it claims it so shared
        // triangles count once. Also derives the insert prune bound
        // K = max supp(inserted) + 2: nothing at or above K can rise. --
        let mut seeds: Vec<EdgeId> = Vec::with_capacity(applied);
        let mut k_bound = 2u32;
        for (e, ins) in inserted.iter().enumerate() {
            if !ins {
                continue;
            }
            let d = e as EdgeId;
            seeds.push(d);
            let mut supp_d = 0u32;
            common_triangles(&new_eg, d, |e2, e3| {
                supp_d += 1;
                let i2 = inserted[e2 as usize];
                let i3 = inserted[e3 as usize];
                if (i2 && e2 < d) || (i3 && e3 < d) {
                    return; // a smaller inserted edge claims this triangle
                }
                if !i2 {
                    sup[e2 as usize] += 1;
                }
                if !i3 {
                    sup[e3 as usize] += 1;
                }
            });
            sup[e] = supp_d;
            k_bound = k_bound.max(supp_d + 2);
        }

        // -- affected region + frozen context --
        let state = self.affected_region(&new_eg, &seeds, &t_prev, token, |t| t >= k_bound)?;

        self.repeel_and_commit(
            UpdateOp::Insert,
            new_eg,
            state,
            t_prev,
            sup,
            Some(inserted),
            batch.len(),
            applied,
            sp,
            token,
        )
    }

    /// Remove a batch of edges. Self-loops, duplicates and absent edges
    /// are skipped (counted in the report). Vertices are never removed.
    pub fn remove_batch(&mut self, batch: &[(Vertex, Vertex)]) -> UpdateReport {
        match self.remove_batch_with(batch, &CancelToken::never()) {
            Ok(r) => r,
            Err(c) => unreachable!("remove cancelled without a token: {c}"),
        }
    }

    /// [`DynamicTruss::remove_batch`] with cooperative cancellation.
    pub fn remove_batch_with(
        &mut self,
        batch: &[(Vertex, Vertex)],
        token: &CancelToken,
    ) -> Result<UpdateReport, Cancelled> {
        let nb = batch.len().to_string();
        let sp = obs::span_with("dynamic.remove", &[("batch", &nb)]);
        dyn_obs().removes.inc();
        if token.should_stop().is_some() {
            return Err(token.stopped("dynamic.remove", "before batch".into()));
        }

        // -- normalize to old edge ids --
        let m_old = self.eg.m();
        let old_n = self.eg.n() as Vertex;
        let mut deleted = vec![false; m_old];
        let mut applied = 0usize;
        let mut max_deleted_t = 0u32;
        for &(a, b) in batch {
            if a == b {
                continue;
            }
            let (u, v) = if a < b { (a, b) } else { (b, a) };
            if v >= old_n {
                continue; // endpoint outside the graph: nothing to remove
            }
            let Some(d) = self.eg.edge_id(u, v) else { continue };
            if !deleted[d as usize] {
                deleted[d as usize] = true;
                applied += 1;
                max_deleted_t = max_deleted_t.max(self.trussness[d as usize]);
            }
        }
        if applied == 0 {
            return Ok(self.noop_report(UpdateOp::Remove, batch.len(), sp.close()));
        }

        // -- rebuild the CSR on the survivors (n is preserved) --
        let sp_build = obs::span("dynamic.rebuild");
        let edges: Vec<(Vertex, Vertex)> = self
            .eg
            .el
            .iter()
            .enumerate()
            .filter(|&(e, _)| !deleted[e])
            .map(|(_, &uv)| uv)
            .collect();
        let new_g =
            GraphBuilder::new().num_vertices(self.eg.n()).edges_vec(edges).build();
        let new_eg = EdgeGraph::new(new_g);
        sp_build.close();
        let m_new = new_eg.m();
        debug_assert_eq!(m_new, m_old - applied);

        // -- carry old state across; survivors keep lexicographic order
        // so old id → new id is a filtering merge --
        let mut t_prev = vec![0u32; m_new];
        let mut sup = vec![0u32; m_new];
        let mut old_to_new = vec![EdgeId::MAX; m_old];
        let mut ne = 0usize;
        for (oe, del) in deleted.iter().enumerate() {
            if *del {
                continue;
            }
            debug_assert_eq!(new_eg.el[ne], self.eg.el[oe]);
            t_prev[ne] = self.trussness[oe];
            sup[ne] = self.support[oe];
            old_to_new[oe] = ne as EdgeId;
            ne += 1;
        }
        debug_assert_eq!(ne, m_new);

        // -- incremental support + seeds: every OLD-graph triangle
        // through a deleted edge dies; the lowest deleted edge id in it
        // claims it. Surviving partners lose one support each and seed
        // the affected BFS (unless pruned: an edge with trussness above
        // every deleted edge's cannot drop — its old k-truss is intact).
        let mut seeds: Vec<EdgeId> = Vec::new();
        let mut seeded = vec![false; m_new];
        for (oe, del) in deleted.iter().enumerate() {
            if !del {
                continue;
            }
            let d = oe as EdgeId;
            common_triangles(&self.eg, d, |e2, e3| {
                let d2 = deleted[e2 as usize];
                let d3 = deleted[e3 as usize];
                if (d2 && e2 < d) || (d3 && e3 < d) {
                    return; // a smaller deleted edge claims this triangle
                }
                for f in [e2, e3] {
                    if deleted[f as usize] {
                        continue;
                    }
                    let nf = old_to_new[f as usize] as usize;
                    sup[nf] -= 1;
                    if !seeded[nf] && self.trussness[f as usize] <= max_deleted_t {
                        seeded[nf] = true;
                        seeds.push(nf as EdgeId);
                    }
                }
            });
        }

        // -- affected region + frozen context --
        let state =
            self.affected_region(&new_eg, &seeds, &t_prev, token, |t| t > max_deleted_t)?;

        self.repeel_and_commit(
            UpdateOp::Remove,
            new_eg,
            state,
            t_prev,
            sup,
            None,
            batch.len(),
            applied,
            sp,
            token,
        )
    }

    /// Check the maintained state against a from-scratch recompute and
    /// a serial support recount ([`crate::validate::check_dynamic`]).
    pub fn validate_maintained(&self) -> crate::validate::Report {
        let mut rep = crate::validate::Report::new();
        crate::validate::check_dynamic(
            &self.eg,
            &self.support,
            &self.trussness,
            &Pool::new(self.threads),
            &self.cfg,
            &mut rep,
        );
        rep
    }

    /// Triangle-adjacency BFS from `seeds` over the new graph: the
    /// closure of change candidates. `pruned(t_prev)` decides that an
    /// edge provably cannot change — it becomes frozen [`CONTEXT`]
    /// (present in the re-peel, pinned, never expanded); everything
    /// else joins [`AFFECTED`] and keeps expanding. Soundness rests on
    /// the cascade lemma (module docs): every changed edge shares a
    /// triangle with an edited or another changed edge, so the closure
    /// over non-pruned edges covers all of them.
    fn affected_region(
        &self,
        new_eg: &EdgeGraph,
        seeds: &[EdgeId],
        t_prev: &[u32],
        token: &CancelToken,
        pruned: impl Fn(u32) -> bool,
    ) -> Result<Vec<u8>, Cancelled> {
        let sp = obs::span("dynamic.affected");
        let mut state = vec![UNSEEN; new_eg.m()];
        let mut queue: VecDeque<EdgeId> = VecDeque::with_capacity(seeds.len());
        for &s in seeds {
            state[s as usize] = AFFECTED;
            queue.push_back(s);
        }
        let mut expansions = 0usize;
        while let Some(e) = queue.pop_front() {
            expansions += 1;
            if expansions % BFS_POLL == 0 && token.should_stop().is_some() {
                return Err(token
                    .stopped("dynamic.affected", format!("expanded={expansions}")));
            }
            common_triangles(new_eg, e, |e2, e3| {
                for f in [e2, e3] {
                    let fi = f as usize;
                    if state[fi] != UNSEEN {
                        continue;
                    }
                    if pruned(t_prev[fi]) {
                        state[fi] = CONTEXT;
                    } else {
                        state[fi] = AFFECTED;
                        queue.push_back(f);
                    }
                }
            });
        }
        sp.close();
        Ok(state)
    }

    /// Phases 3 + 4: compact the region, recount affected supports,
    /// pin + freeze context edges, re-peel, then commit the new state.
    /// Nothing in `self` mutates until every fallible step has passed.
    #[allow(clippy::too_many_arguments)]
    fn repeel_and_commit(
        &mut self,
        op: UpdateOp,
        new_eg: EdgeGraph,
        state: Vec<u8>,
        t_prev: Vec<u32>,
        sup: Vec<u32>,
        inserted: Option<Vec<bool>>,
        requested: usize,
        applied: usize,
        sp: obs::Span,
        token: &CancelToken,
    ) -> Result<UpdateReport, Cancelled> {
        let affected = state.iter().filter(|&&s| s == AFFECTED).count();
        let context = state.iter().filter(|&&s| s == CONTEXT).count();
        dyn_obs().affected.add(affected as u64);

        let mut t_new = t_prev;
        let mut changed = 0usize;
        let mut levels = 0u32;
        if affected > 0 {
            let pool = Pool::new(self.threads);
            let comp = compact_edges(&new_eg, &pool, |e| state[e as usize] != UNSEEN);
            let rsup = support_am4_with(&comp.eg, &pool, token)?;
            let rm = comp.eg.m();
            let frozen = AtomicBitset::new(rm);
            let s: Vec<AtomicI32> = (0..rm)
                .map(|r| {
                    let full = comp.old_of_new[r] as usize;
                    if state[full] == CONTEXT {
                        frozen.set(r);
                        // pinned at its known level: trussness - 2
                        AtomicI32::new(t_new[full] as i32 - 2)
                    } else {
                        // affected: all of its new-graph triangles are in
                        // the region, so the region recount is exact
                        AtomicI32::new(rsup[r].load(Ordering::Relaxed) as i32)
                    }
                })
                .collect();
            let res = pkt_region_peel(&comp.eg, &pool, s, frozen, &self.cfg, token)?;
            levels = res.stats.levels;
            for r in 0..rm {
                let full = comp.old_of_new[r] as usize;
                if state[full] == AFFECTED {
                    let fresh_edge =
                        inserted.as_ref().is_some_and(|ins| ins[full]);
                    if fresh_edge || res.trussness[r] != t_new[full] {
                        changed += 1;
                    }
                    t_new[full] = res.trussness[r];
                } else {
                    debug_assert_eq!(
                        res.trussness[r],
                        t_new[full],
                        "frozen context edge must re-peel to its pinned trussness"
                    );
                }
            }
        } else if let Some(ins) = &inserted {
            // no region peel, but brand-new edges still need a value;
            // with no triangles (affected would be nonempty otherwise,
            // since inserted edges always seed) trussness is 2
            for (e, i) in ins.iter().enumerate() {
                if *i {
                    t_new[e] = 2;
                    changed += 1;
                }
            }
        }

        self.eg = new_eg;
        self.trussness = t_new;
        self.support = sup;

        let report = UpdateReport {
            op,
            requested,
            applied,
            skipped: requested - applied,
            affected,
            context,
            changed,
            levels,
            t_max: self.t_max(),
            n: self.eg.n(),
            m: self.eg.m(),
            secs: sp.close(),
        };
        if crate::validate::enabled() {
            self.validate_maintained().panic_if_failed(match op {
                UpdateOp::Insert => "dynamic.insert",
                UpdateOp::Remove => "dynamic.remove",
            });
        }
        Ok(report)
    }

    /// Report for a batch that normalized to nothing.
    fn noop_report(&self, op: UpdateOp, requested: usize, secs: f64) -> UpdateReport {
        UpdateReport {
            op,
            requested,
            applied: 0,
            skipped: requested,
            affected: 0,
            context: 0,
            changed: 0,
            levels: 0,
            t_max: self.t_max(),
            n: self.eg.n(),
            m: self.eg.m(),
            secs,
        }
    }
}

/// Enumerate the triangles through edge `e = <u, v>` by a sorted merge
/// of the two endpoint rows; yields the other two edge ids `(e2, e3)`
/// with `e2` on the `u` side and `e3` on the `v` side. Serial — the
/// affected BFS visits each region edge once and the merge touches
/// `d(u) + d(v)` entries, so this stays linear in region volume.
fn common_triangles(eg: &EdgeGraph, e: EdgeId, mut f: impl FnMut(EdgeId, EdgeId)) {
    let g = &eg.g;
    let (u, v) = eg.el[e as usize];
    let (mut a, ahi) = (g.xadj[u as usize], g.xadj[u as usize + 1]);
    let (mut b, bhi) = (g.xadj[v as usize], g.xadj[v as usize + 1]);
    while a < ahi && b < bhi {
        let (wu, wv) = (g.adj[a], g.adj[b]);
        match wu.cmp(&wv) {
            std::cmp::Ordering::Less => a += 1,
            std::cmp::Ordering::Greater => b += 1,
            std::cmp::Ordering::Equal => {
                f(eg.eid[a], eg.eid[b]);
                a += 1;
                b += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::truss::pkt;
    use crate::util::forall;

    fn fresh(eg: &EdgeGraph, threads: usize) -> Vec<u32> {
        pkt(eg, &Pool::new(threads)).trussness
    }

    /// Assert the maintained state equals a from-scratch recompute on
    /// the same graph (ids align because both sides are lexicographic).
    fn assert_oracle(dt: &DynamicTruss) {
        let want = fresh(dt.eg(), 2);
        assert_eq!(dt.trussness(), &want[..], "maintained trussness diverged");
        let rep = dt.validate_maintained();
        assert!(rep.ok(), "{}", rep.error().unwrap_or_default());
    }

    #[test]
    fn insert_builds_triangle() {
        // path 0-1-2: all trussness 2; closing the triangle lifts all
        // three edges to 3
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2)]).build();
        let mut dt = DynamicTruss::new(g, 2);
        assert!(dt.trussness().iter().all(|&t| t == 2));
        let r = dt.insert_batch(&[(0, 2)]);
        assert_eq!(r.applied, 1);
        assert_eq!(r.t_max, 3);
        assert!(dt.trussness().iter().all(|&t| t == 3), "{:?}", dt.trussness());
        assert_oracle(&dt);
    }

    #[test]
    fn remove_breaks_clique() {
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        let g = GraphBuilder::new().edges_vec(edges).build();
        let mut dt = DynamicTruss::new(g, 2);
        assert_eq!(dt.t_max(), 5);
        let r = dt.remove_batch(&[(0, 1)]);
        assert_eq!(r.applied, 1);
        assert_eq!(r.m, 9);
        assert_oracle(&dt);
    }

    #[test]
    fn dirty_batches_are_skipped() {
        let g = gen::complete(4);
        let mut dt = DynamicTruss::new(g, 1);
        // self-loop, duplicate, already present
        let r = dt.insert_batch(&[(0, 0), (0, 1), (1, 0), (5, 6), (5, 6), (6, 5)]);
        assert_eq!(r.applied, 1, "{}", r.summary());
        assert_eq!(r.skipped, 5);
        assert_eq!(r.m, 7);
        assert_oracle(&dt);
        // absent edge, self-loop, duplicate
        let r = dt.remove_batch(&[(0, 9), (2, 2), (5, 6), (6, 5)]);
        assert_eq!(r.applied, 1, "{}", r.summary());
        assert_eq!(r.skipped, 3);
        assert_oracle(&dt);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let g = gen::complete(4);
        let mut dt = DynamicTruss::new(g, 1);
        let before = dt.trussness().to_vec();
        let r = dt.insert_batch(&[]);
        assert_eq!((r.applied, r.skipped, r.changed), (0, 0, 0));
        let r = dt.remove_batch(&[(0, 0)]);
        assert_eq!((r.applied, r.skipped), (0, 1));
        assert_eq!(dt.trussness(), &before[..]);
    }

    #[test]
    fn insert_grows_vertex_set() {
        let g = gen::complete(3);
        let mut dt = DynamicTruss::new(g, 1);
        let r = dt.insert_batch(&[(2, 7)]);
        assert_eq!(r.applied, 1);
        assert_eq!(r.n, 8);
        assert_oracle(&dt);
    }

    #[test]
    fn remove_everything() {
        let g = gen::complete(4);
        let mut dt = DynamicTruss::new(g, 2);
        let all: Vec<_> = dt.eg().el.clone();
        let r = dt.remove_batch(&all);
        assert_eq!(r.applied, 6);
        assert_eq!(r.m, 0);
        assert_eq!(dt.trussness().len(), 0);
        assert_eq!(dt.n(), 4, "vertices are never removed");
    }

    #[test]
    fn interleaved_batches_match_oracle() {
        forall("dynamic-interleaved", 8, |rng| {
            let n = rng.range(8, 40);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let mut dt = DynamicTruss::new(g, 2);
            for _ in 0..4 {
                let mut batch = vec![];
                for _ in 0..rng.range(1, 9) {
                    let u = rng.below(n as u64) as Vertex;
                    let v = rng.below(n as u64) as Vertex;
                    batch.push((u, v));
                }
                if rng.chance(0.5) {
                    dt.insert_batch(&batch);
                } else {
                    dt.remove_batch(&batch);
                }
                assert_oracle(&dt);
            }
        });
    }

    #[test]
    fn frozen_context_stays_pinned() {
        // two K5s sharing nothing, bridged by one edge: deleting inside
        // one clique must not touch the other (it lands in context or
        // stays unseen, and its trussness is carried, not recomputed)
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
                edges.push((u + 5, v + 5));
            }
        }
        edges.push((4, 5));
        let g = GraphBuilder::new().edges_vec(edges).build();
        let mut dt = DynamicTruss::new(g, 2);
        let r = dt.remove_batch(&[(0, 1)]);
        assert!(r.affected < dt.m(), "locality: {}", r.summary());
        assert_oracle(&dt);
    }

    #[test]
    fn cancellation_leaves_state_intact() {
        let g = gen::erdos_renyi(60, 0.3, 7);
        let mut dt = DynamicTruss::new(g, 2);
        let before_t = dt.trussness().to_vec();
        let before_m = dt.m();
        let token = CancelToken::never();
        token.cancel();
        let err = dt.insert_batch_with(&[(0, 61), (1, 62)], &token).unwrap_err();
        assert_eq!(err.reason, crate::par::CancelReason::Cancelled);
        assert_eq!(dt.m(), before_m, "no partial mutation on cancel");
        assert_eq!(dt.trussness(), &before_t[..]);
    }

    #[test]
    fn corrupted_state_is_caught_by_validate() {
        let g = gen::complete(5);
        let mut dt = DynamicTruss::new(g, 1);
        dt.insert_batch(&[(0, 5), (1, 5)]);
        assert!(dt.validate_maintained().ok());
        // corrupt the maintained trussness: the differential check must
        // flag exactly this class of silent maintenance bug
        dt.trussness[0] += 1;
        let rep = dt.validate_maintained();
        assert!(!rep.ok(), "corrupted trussness must be detected");
        assert!(rep.error().unwrap().contains("dynamic.trussness"));
        dt.trussness[0] -= 1;
        // corrupt the maintained support: caught by the recount
        dt.support[3] += 1;
        let rep = dt.validate_maintained();
        assert!(!rep.ok(), "corrupted support must be detected");
    }

    #[test]
    fn update_metrics_and_report_fields() {
        let before = obs::global()
            .counter("dynamic_updates_total", &[("op", "insert")])
            .get();
        let g = gen::complete(4);
        let mut dt = DynamicTruss::new(g, 1);
        let r = dt.insert_batch(&[(0, 4), (1, 4)]);
        assert_eq!(r.op, UpdateOp::Insert);
        assert_eq!(r.requested, 2);
        assert!(r.secs > 0.0);
        assert!(r.affected >= 2, "inserted edges are always affected");
        assert!(r.summary().contains("op=insert"), "{}", r.summary());
        let after = obs::global()
            .counter("dynamic_updates_total", &[("op", "insert")])
            .get();
        assert!(after >= before + 1);
    }
}
