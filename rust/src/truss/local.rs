//! Local truss decomposition by h-index iteration — the
//! synchronization-free alternative the paper discusses in §2
//! (Sariyüce, Seshadhri & Pinar [19]; the truss analogue of the MPM
//! k-core update rule [34]).
//!
//! Every edge repeatedly replaces its estimate ρ(e) with the h-index of
//! `{ min(ρ(f), ρ(g)) : (e, f, g) ∈ triangles }`. Starting from the
//! initial supports, the estimates decrease monotonically to the
//! trussness−2 fixpoint. Not work-efficient (each triangle is touched
//! every round) but embarrassingly parallel — no frontier, no ownership
//! rule, just a barrier per round.

use crate::graph::EdgeGraph;
use crate::par::{Counter, Pool, CHUNK_PROCESS};
use crate::triangle::support_am4;
use crate::truss::{PktStats, TrussResult};
use crate::par::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::time::Instant;

/// Run the local algorithm. `max_rounds` caps the iteration count
/// (usually converges in far fewer; the cap guards pathological inputs —
/// convergence is reached when a full round changes nothing).
pub fn local(eg: &EdgeGraph, pool: &Pool, max_rounds: u32) -> TrussResult {
    let t0 = Instant::now();
    let n = eg.n();
    let m = eg.m();
    let g = &eg.g;

    let rho: Vec<AtomicU32> = support_am4(eg, pool);
    let support_secs = t0.elapsed().as_secs_f64();
    let rho_new: Vec<AtomicU32> = (0..m).map(|_| AtomicU32::new(0)).collect();
    let changed = AtomicBool::new(true);
    let rounds = AtomicU64::new(0);
    let counter = Counter::new();

    pool.region(|ctx| {
        let mut x = vec![0usize; n];
        let mut vals: Vec<u32> = Vec::new();
        let mut round = 0u32;
        loop {
            if !changed.load(Ordering::Acquire) || round >= max_rounds {
                break;
            }
            ctx.barrier();
            if ctx.tid == 0 {
                changed.store(false, Ordering::Release);
                counter.reset();
                rounds.fetch_add(1, Ordering::Relaxed);
            }
            ctx.barrier();
            ctx.for_dynamic(&counter, m, CHUNK_PROCESS, |e1| {
                let (u, v) = eg.el[e1];
                vals.clear();
                let (ulo, uhi) = (g.xadj[u as usize], g.xadj[u as usize + 1]);
                for j in ulo..uhi {
                    x[g.adj[j] as usize] = j + 1;
                }
                let (vlo, vhi) = (g.xadj[v as usize], g.xadj[v as usize + 1]);
                for j in vlo..vhi {
                    let w = g.adj[j];
                    if w == u {
                        continue;
                    }
                    let xw = x[w as usize];
                    if xw == 0 {
                        continue;
                    }
                    let e2 = eg.eid[j] as usize;
                    let e3 = eg.eid[xw - 1] as usize;
                    vals.push(
                        rho[e2]
                            .load(Ordering::Relaxed)
                            .min(rho[e3].load(Ordering::Relaxed)),
                    );
                }
                for j in ulo..uhi {
                    x[g.adj[j] as usize] = 0;
                }
                let h = h_index(&mut vals);
                let old = rho[e1].load(Ordering::Relaxed);
                let new = h.min(old); // monotone non-increasing
                rho_new[e1].store(new, Ordering::Relaxed);
                if new != old {
                    changed.store(true, Ordering::Release);
                }
            });
            ctx.barrier();
            // commit the round (static copy)
            ctx.for_static(m, |e| {
                rho[e].store(rho_new[e].load(Ordering::Relaxed), Ordering::Relaxed);
            });
            ctx.barrier();
            round += 1;
        }
    });

    let total = t0.elapsed().as_secs_f64();
    TrussResult {
        trussness: rho
            .iter()
            .map(|a| a.load(Ordering::Relaxed) + 2)
            .collect(),
        stats: PktStats {
            support_secs,
            process_secs: total - support_secs,
            total_secs: total,
            levels: rounds.into_inner() as u32, // rounds, for reporting
            ..Default::default()
        },
    }
}

/// h-index of a value multiset: the largest h such that at least h
/// values are ≥ h. Sorts descending in place.
fn h_index(vals: &mut [u32]) -> u32 {
    vals.sort_unstable_by(|a, b| b.cmp(a));
    let mut h = 0u32;
    for (i, &v) in vals.iter().enumerate() {
        if v as usize > i {
            h = (i + 1) as u32;
        } else {
            break;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::truss::pkt;
    use crate::util::forall;

    #[test]
    fn h_index_basics() {
        assert_eq!(h_index(&mut []), 0);
        assert_eq!(h_index(&mut [0]), 0);
        assert_eq!(h_index(&mut [1]), 1);
        assert_eq!(h_index(&mut [5]), 1);
        assert_eq!(h_index(&mut [1, 1, 1]), 1);
        assert_eq!(h_index(&mut [2, 2, 2]), 2);
        assert_eq!(h_index(&mut [3, 2, 1]), 2);
        assert_eq!(h_index(&mut [10, 10, 10, 10]), 4);
    }

    #[test]
    fn local_complete_graph() {
        let eg = EdgeGraph::new(gen::complete(7));
        let t = local(&eg, &Pool::new(2), 1000).trussness;
        assert!(t.iter().all(|&x| x == 7));
    }

    #[test]
    fn local_matches_pkt() {
        forall("local-eq-pkt", 10, |rng| {
            let n = rng.range(4, 60);
            let g = gen::erdos_renyi(n, 0.25, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let l = local(&eg, &Pool::new(2), 10_000).trussness;
            let p = pkt(&eg, &Pool::new(2)).trussness;
            assert_eq!(l, p);
        });
    }

    #[test]
    fn local_clustered() {
        let g = gen::planted_partition(3, 14, 0.8, 0.02, 8);
        let eg = EdgeGraph::new(g);
        assert_eq!(
            local(&eg, &Pool::new(4), 10_000).trussness,
            pkt(&eg, &Pool::new(1)).trussness
        );
    }

    #[test]
    fn local_reports_rounds() {
        let g = gen::planted_partition(2, 12, 0.9, 0.05, 2);
        let eg = EdgeGraph::new(g);
        let res = local(&eg, &Pool::new(2), 1000);
        assert!(res.stats.levels >= 1, "at least one round");
    }

    #[test]
    fn local_empty() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        assert!(local(&eg, &Pool::new(1), 10).trussness.is_empty());
    }
}
