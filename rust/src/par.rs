//! The shared-memory parallel runtime substrate.
//!
//! The paper's OpenMP idioms, rebuilt on `std::thread` + atomics (no
//! external crates are available offline):
//!
//! - [`Pool::region`] — an OpenMP `parallel` region: `t` scoped threads
//!   run the same closure, coordinating through [`RegionCtx::barrier`];
//! - [`RegionCtx::for_dynamic`] — `omp for schedule(dynamic, chunk)`:
//!   work distributed chunk-at-a-time from a shared atomic counter;
//! - [`RegionCtx::for_static`] — `omp for schedule(static)`: contiguous
//!   per-thread slabs (used by the SCAN phase, like the paper);
//! - [`AtomicVec`] — a fixed-capacity concurrent append buffer: the
//!   `curr`/`next` frontier arrays with the paper's thread-local `buff`
//!   batching (one atomic fetch-add per `s` items instead of per item).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, OnceLock};
use std::time::Instant;

/// Default chunk sizes from the paper's §4.1 (support computation: 10,
/// edge processing: 4).
pub const CHUNK_SUPPORT: usize = 10;
pub const CHUNK_PROCESS: usize = 4;
/// Thread-local frontier buffer size (`buff` in Alg. 4/5).
pub const BUFF_SIZE: usize = 256;

/// Load-imbalance ratio buckets (max-items / mean-items per region):
/// 1.0 is perfect balance, the tail captures pathological skew.
const IMBALANCE_BUCKETS: &[f64] = &[1.0, 1.02, 1.05, 1.1, 1.2, 1.5, 2.0, 3.0, 5.0, 10.0];

/// Cached handles into the global metric registry — looked up once,
/// then updated lock-free from inside regions.
struct ParObs {
    regions: crate::obs::Counter,
    chunks: crate::obs::Counter,
    items: crate::obs::Counter,
    barrier_waits: crate::obs::Counter,
    barrier_secs: crate::obs::Histogram,
    imbalance: crate::obs::Gauge,
    imbalance_hist: crate::obs::Histogram,
}

fn par_obs() -> &'static ParObs {
    static OBS: OnceLock<ParObs> = OnceLock::new();
    OBS.get_or_init(|| {
        let r = crate::obs::global();
        ParObs {
            regions: r.counter("par_regions_total", &[]),
            chunks: r.counter("par_chunks_dispatched_total", &[]),
            items: r.counter("par_items_total", &[]),
            barrier_waits: r.counter("par_barrier_waits_total", &[]),
            barrier_secs: r.histogram("par_barrier_wait_seconds", &[]),
            imbalance: r.gauge("par_load_imbalance", &[]),
            imbalance_hist: r.histogram_with_buckets(
                "par_load_imbalance_ratio",
                &[],
                IMBALANCE_BUCKETS,
            ),
        }
    })
}

/// A parallel execution pool. Threads are spawned per region (scoped),
/// so a `Pool` is just a thread-count policy object; persistent state
/// (counters, frontiers) lives in the algorithm.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    nthreads: usize,
}

impl Pool {
    pub fn new(nthreads: usize) -> Self {
        Self { nthreads: nthreads.max(1) }
    }

    /// Thread count from `TRUSSX_THREADS` or the machine's parallelism.
    pub fn default_threads() -> usize {
        std::env::var("TRUSSX_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            })
    }

    pub fn with_default_threads() -> Self {
        Self::new(Self::default_threads())
    }

    #[inline]
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Run an OpenMP-style parallel region: `nthreads` threads execute
    /// `f(&ctx)`; the call returns when all threads finish. With one
    /// thread the closure runs inline (no spawn overhead) — this is the
    /// path sequential baselines use.
    pub fn region<F>(&self, f: F)
    where
        F: Fn(&RegionCtx) + Sync,
    {
        let t = self.nthreads;
        let obs = par_obs();
        obs.regions.inc();
        let barrier = Barrier::new(t);
        let item_counts: Vec<AtomicU64> = (0..t).map(|_| AtomicU64::new(0)).collect();
        if t == 1 {
            f(&RegionCtx { tid: 0, nthreads: 1, barrier: &barrier, items: &item_counts[0] });
        } else {
            std::thread::scope(|scope| {
                for tid in 0..t {
                    let f = &f;
                    let barrier = &barrier;
                    let items = &item_counts[tid];
                    scope.spawn(move || {
                        f(&RegionCtx { tid, nthreads: t, barrier, items });
                    });
                }
            });
        }
        // per-region load accounting: total items done, and how far the
        // busiest thread ran ahead of the mean (1.0 = perfectly balanced)
        let per_thread: Vec<u64> = item_counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = per_thread.iter().sum();
        if total > 0 {
            obs.items.add(total);
            if t > 1 {
                let max = *per_thread.iter().max().unwrap_or(&0);
                let ratio = max as f64 * t as f64 / total as f64;
                obs.imbalance.set(ratio);
                obs.imbalance_hist.observe(ratio);
            }
        }
    }

    /// One-shot dynamic parallel-for over `0..total` (its own region).
    pub fn for_dynamic<F>(&self, total: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let counter = AtomicUsize::new(0);
        self.region(|ctx| {
            dynamic_items(&counter, total, chunk, ctx.items, &f);
        });
    }
}

/// Per-thread context inside a [`Pool::region`].
pub struct RegionCtx<'a> {
    pub tid: usize,
    pub nthreads: usize,
    barrier: &'a Barrier,
    /// Items this thread has executed in this region (load accounting;
    /// fed by `for_dynamic` / `for_static`).
    items: &'a AtomicU64,
}

impl RegionCtx<'_> {
    /// OpenMP `barrier`. Counted and timed: waiting at a barrier is
    /// exactly the load-imbalance cost the paper's §4 discusses.
    #[inline]
    pub fn barrier(&self) {
        let obs = par_obs();
        obs.barrier_waits.inc();
        let t0 = Instant::now();
        self.barrier.wait();
        obs.barrier_secs.observe(t0.elapsed().as_secs_f64());
    }

    /// `schedule(dynamic, chunk)` over `0..total`, driven by a shared
    /// counter the caller resets between uses (see [`Counter`]).
    #[inline]
    pub fn for_dynamic<F>(&self, counter: &Counter, total: usize, chunk: usize, f: F)
    where
        F: FnMut(usize),
    {
        dynamic_items(&counter.0, total, chunk, self.items, f);
    }

    /// `schedule(static)` over `0..total`: thread `tid` gets the
    /// contiguous range `[lo, hi)`.
    #[inline]
    pub fn static_range(&self, total: usize) -> (usize, usize) {
        let per = total.div_ceil(self.nthreads);
        let lo = (self.tid * per).min(total);
        let hi = ((self.tid + 1) * per).min(total);
        (lo, hi)
    }

    /// Convenience static-schedule loop.
    #[inline]
    pub fn for_static<F>(&self, total: usize, mut f: F)
    where
        F: FnMut(usize),
    {
        let (lo, hi) = self.static_range(total);
        for i in lo..hi {
            f(i);
        }
        if hi > lo {
            self.items.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        }
    }
}

#[inline]
fn dynamic_items<F>(counter: &AtomicUsize, total: usize, chunk: usize, items: &AtomicU64, mut f: F)
where
    F: FnMut(usize),
{
    let chunk = chunk.max(1);
    let obs = par_obs();
    let mut done = 0u64;
    let mut chunks = 0u64;
    loop {
        let start = counter.fetch_add(chunk, Ordering::Relaxed);
        if start >= total {
            break;
        }
        let end = (start + chunk).min(total);
        chunks += 1;
        done += (end - start) as u64;
        for i in start..end {
            f(i);
        }
    }
    if chunks > 0 {
        obs.chunks.add(chunks);
        items.fetch_add(done, Ordering::Relaxed);
    }
}

/// A resettable shared work counter for dynamic scheduling inside a
/// region. Reset from a single thread between barriers.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }

    /// Reset to zero (call from one thread, between barriers).
    #[inline]
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Fixed-capacity vector supporting concurrent batched appends — the
/// `curr` / `next` frontier arrays of Alg. 4/5.
///
/// Safety model: writers reserve disjoint ranges with one `fetch_add`
/// and copy their batch into the reservation; reads of `as_slice` must
/// be separated from writes by a barrier (the level-synchronous
/// structure guarantees this). `clear` must also be barrier-separated.
pub struct AtomicVec<T: Copy> {
    buf: UnsafeCell<Box<[MaybeUninit<T>]>>,
    len: AtomicUsize,
}

// SAFETY: disjoint-reservation writes + barrier-separated reads, as
// documented above; T: Copy keeps drops trivial.
unsafe impl<T: Copy + Send> Send for AtomicVec<T> {}
unsafe impl<T: Copy + Send> Sync for AtomicVec<T> {}

impl<T: Copy> AtomicVec<T> {
    pub fn with_capacity(cap: usize) -> Self {
        let mut v: Vec<MaybeUninit<T>> = Vec::with_capacity(cap);
        // SAFETY: MaybeUninit contents need no initialization.
        unsafe { v.set_len(cap) };
        Self {
            buf: UnsafeCell::new(v.into_boxed_slice()),
            len: AtomicUsize::new(0),
        }
    }

    /// Append a batch; returns the start offset of the reservation.
    /// Panics if capacity would be exceeded (frontiers are pre-sized to
    /// `m`, which is a hard upper bound).
    pub fn push_batch(&self, items: &[T]) -> usize {
        let start = self.len.fetch_add(items.len(), Ordering::AcqRel);
        let buf = unsafe { &mut *self.buf.get() };
        assert!(
            start + items.len() <= buf.len(),
            "AtomicVec overflow: {} + {} > {}",
            start,
            items.len(),
            buf.len()
        );
        for (i, &x) in items.iter().enumerate() {
            buf[start + i] = MaybeUninit::new(x);
        }
        start
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Acquire)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the current contents. Caller must ensure no writer is
    /// concurrent (barrier-separated phases).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        let len = self.len();
        let buf = unsafe { &*self.buf.get() };
        // SAFETY: elements < len were fully written before the barrier.
        unsafe { std::slice::from_raw_parts(buf.as_ptr() as *const T, len) }
    }

    /// Reset length to zero (single-threaded, barrier-separated).
    #[inline]
    pub fn clear(&self) {
        self.len.store(0, Ordering::Release);
    }
}

/// Per-thread buffered writer into an [`AtomicVec`] — the paper's `buff`
/// trick reducing atomic ops from O(|next|) to O(|next| / s).
pub struct BatchWriter<'a, T: Copy> {
    target: &'a AtomicVec<T>,
    buf: Vec<T>,
}

impl<'a, T: Copy> BatchWriter<'a, T> {
    pub fn new(target: &'a AtomicVec<T>) -> Self {
        Self { target, buf: Vec::with_capacity(BUFF_SIZE) }
    }

    #[inline]
    pub fn push(&mut self, x: T) {
        self.buf.push(x);
        if self.buf.len() == BUFF_SIZE {
            self.flush();
        }
    }

    #[inline]
    pub fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.target.push_batch(&self.buf);
            self.buf.clear();
        }
    }
}

impl<T: Copy> Drop for BatchWriter<'_, T> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Fixed-length concurrent bitset: one bit per flag, packed 64 per word,
/// mutated with word-level `fetch_or` / `fetch_and`.
///
/// This is the packed replacement for the peel's `Vec<AtomicBool>` flag
/// arrays (`processed` / `inCurr` / `inNext`): an 8× reduction in flag
/// memory and scan bandwidth, which is exactly the traffic the paper's
/// §4 identifies as the bottleneck on its 24-core server.
///
/// All operations are `Relaxed`: like the byte-wide flags they replace,
/// cross-phase visibility comes from the region barriers, not from the
/// flag accesses themselves. Two threads touching different bits of the
/// same word stay correct (the RMW is atomic), they just contend.
pub struct AtomicBitset {
    words: Box<[AtomicU64]>,
    len: usize,
}

impl AtomicBitset {
    /// A bitset of `len` bits, all zero.
    pub fn new(len: usize) -> Self {
        let words = (0..len.div_ceil(64)).map(|_| AtomicU64::new(0)).collect();
        Self { words, len }
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6].load(Ordering::Relaxed) >> (i & 63)) & 1 != 0
    }

    /// Set bit `i` to 1.
    #[inline]
    pub fn set(&self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6].fetch_or(1 << (i & 63), Ordering::Relaxed);
    }

    /// Set bit `i` to 0.
    #[inline]
    pub fn clear(&self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6].fetch_and(!(1 << (i & 63)), Ordering::Relaxed);
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.load(Ordering::Relaxed).count_ones() as usize).sum()
    }

    /// Zero every bit (single-threaded, barrier-separated).
    pub fn clear_all(&self) {
        for w in self.words.iter() {
            w.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn region_runs_all_threads() {
        let pool = Pool::new(4);
        let hits = AtomicU64::new(0);
        pool.region(|ctx| {
            hits.fetch_add(1 << (8 * ctx.tid), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 0x01010101);
    }

    #[test]
    fn single_thread_region_inline() {
        let pool = Pool::new(1);
        // would not compile with FnMut across threads; single-thread path
        // still must run exactly once
        let hit_cell = std::sync::atomic::AtomicBool::new(false);
        pool.region(|ctx| {
            assert_eq!(ctx.nthreads, 1);
            hit_cell.store(true, Ordering::Relaxed);
        });
        assert!(hit_cell.load(Ordering::Relaxed));
    }

    #[test]
    fn dynamic_for_covers_all_items_once() {
        let pool = Pool::new(4);
        let total = 10_007;
        let marks: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
        pool.for_dynamic(total, 7, |i| {
            marks[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(marks.iter().all(|m| m.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn static_ranges_partition() {
        let pool = Pool::new(3);
        let ctxs: Vec<(usize, usize)> = {
            let out: Vec<_> = (0..3)
                .map(|tid| {
                    let ctx = RegionCtx {
                        tid,
                        nthreads: 3,
                        barrier: &Barrier::new(1),
                        items: &AtomicU64::new(0),
                    };
                    ctx.static_range(10)
                })
                .collect();
            out
        };
        let _ = pool;
        assert_eq!(ctxs, vec![(0, 4), (4, 8), (8, 10)]);
    }

    #[test]
    fn regions_record_work_metrics() {
        // the registry is process-global and shared with other tests, so
        // assert monotone deltas rather than absolute values
        let obs = par_obs();
        let (r0, i0, c0, b0) = (
            obs.regions.get(),
            obs.items.get(),
            obs.chunks.get(),
            obs.barrier_waits.get(),
        );
        let pool = Pool::new(3);
        let total = 1000;
        pool.for_dynamic(total, 7, |_| {});
        pool.region(|ctx| {
            ctx.for_static(total, |_| {});
            ctx.barrier();
        });
        // other tests may run concurrently, so the deltas are lower bounds
        assert!(obs.regions.get() - r0 >= 2);
        assert!(obs.items.get() - i0 >= 2 * total as u64);
        assert!(obs.chunks.get() - c0 >= total.div_ceil(7) as u64);
        assert!(obs.barrier_waits.get() - b0 >= 3, "one wait per thread");
    }

    #[test]
    fn barrier_separates_phases() {
        let pool = Pool::new(4);
        let phase1 = AtomicUsize::new(0);
        let ok = AtomicUsize::new(0);
        pool.region(|ctx| {
            phase1.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            // after the barrier every thread must observe all 4 phase-1
            // increments
            if phase1.load(Ordering::SeqCst) == 4 {
                ok.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn atomic_vec_concurrent_batches() {
        let av: AtomicVec<u32> = AtomicVec::with_capacity(40_000);
        let pool = Pool::new(4);
        pool.region(|ctx| {
            let mut w = BatchWriter::new(&av);
            for i in 0..10_000u32 {
                w.push(ctx.tid as u32 * 10_000 + i);
            }
        });
        assert_eq!(av.len(), 40_000);
        let mut all: Vec<u32> = av.as_slice().to_vec();
        all.sort_unstable();
        assert_eq!(all, (0..40_000u32).collect::<Vec<_>>());
    }

    #[test]
    fn atomic_vec_clear_reuse() {
        let av: AtomicVec<u32> = AtomicVec::with_capacity(8);
        av.push_batch(&[1, 2, 3]);
        assert_eq!(av.as_slice(), &[1, 2, 3]);
        av.clear();
        assert!(av.is_empty());
        av.push_batch(&[9]);
        assert_eq!(av.as_slice(), &[9]);
    }

    #[test]
    #[should_panic(expected = "AtomicVec overflow")]
    fn atomic_vec_overflow_panics() {
        let av: AtomicVec<u32> = AtomicVec::with_capacity(2);
        av.push_batch(&[1, 2, 3]);
    }

    #[test]
    fn counter_reset() {
        let c = Counter::new();
        c.0.fetch_add(5, Ordering::Relaxed);
        c.reset();
        assert_eq!(c.0.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pool_threads_from_env_parse() {
        // just exercise the default path; value depends on machine
        assert!(Pool::default_threads() >= 1);
    }

    #[test]
    fn bitset_basic_ops() {
        // length deliberately not a multiple of 64: the last word is
        // partial and word-boundary bits (63, 64, 65) must not alias
        let bs = AtomicBitset::new(130);
        assert_eq!(bs.len(), 130);
        assert!(!bs.is_empty());
        assert_eq!(bs.count_ones(), 0);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!bs.get(i));
            bs.set(i);
            assert!(bs.get(i), "bit {i}");
        }
        assert_eq!(bs.count_ones(), 8);
        // neighbors of the set bits stayed clear
        for i in [2usize, 62, 66, 126] {
            assert!(!bs.get(i), "bit {i}");
        }
        bs.clear(64);
        assert!(!bs.get(64));
        assert!(bs.get(63) && bs.get(65), "clear must not touch siblings");
        assert_eq!(bs.count_ones(), 7);
        bs.clear_all();
        assert_eq!(bs.count_ones(), 0);
    }

    #[test]
    fn bitset_empty() {
        let bs = AtomicBitset::new(0);
        assert!(bs.is_empty());
        assert_eq!(bs.count_ones(), 0);
    }

    #[test]
    fn bitset_concurrent_interleaved_sets() {
        // 4 threads set interleaved bits (thread t owns bits ≡ t mod 4),
        // so every word is hammered by all threads concurrently; no set
        // may be lost and no foreign bit may appear
        let total = 64 * 37 + 13;
        let bs = AtomicBitset::new(total);
        let pool = Pool::new(4);
        pool.region(|ctx| {
            let mut i = ctx.tid;
            while i < total {
                bs.set(i);
                i += ctx.nthreads;
            }
        });
        assert_eq!(bs.count_ones(), total);
        // clear every other bit concurrently; the rest must survive
        pool.region(|ctx| {
            let mut i = ctx.tid * 2;
            while i < total {
                bs.clear(i);
                i += ctx.nthreads * 2;
            }
        });
        assert_eq!(bs.count_ones(), total / 2);
        for i in 0..total {
            assert_eq!(bs.get(i), i % 2 == 1, "bit {i}");
        }
    }
}
