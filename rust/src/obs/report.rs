//! Offline analysis of a captured JSONL trace (see [`crate::obs::sink`]
//! for the event schema): parse the events back and render the
//! phase-summary and per-level tables the paper's figures are built
//! from, via [`crate::metrics::Table`].
//!
//! The parser is a hand-rolled scanner for exactly the JSON subset the
//! sink emits (flat object, string/number fields, one `labels` string
//! map) — std-only, like the rest of the subsystem.

use crate::metrics::Table;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One parsed span event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    pub tid: u64,
    pub ts_us: f64,
    pub dur_us: f64,
    pub labels: Vec<(String, String)>,
}

impl TraceEvent {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse one JSONL line. Returns `None` for blank lines or lines that
/// don't match the sink's schema.
pub fn parse_line(line: &str) -> Option<TraceEvent> {
    let line = line.trim();
    if line.is_empty() {
        return None;
    }
    let name = extract_string(line, "\"name\":\"")?;
    let tid = extract_number(line, "\"tid\":")? as u64;
    let ts_us = extract_number(line, "\"ts_us\":")?;
    let dur_us = extract_number(line, "\"dur_us\":")?;
    let labels = match line.find("\"labels\":{") {
        Some(at) => parse_label_map(&line[at + "\"labels\":{".len()..]),
        None => Vec::new(),
    };
    Some(TraceEvent { name, tid, ts_us, dur_us, labels })
}

/// Read every parseable event from a trace file.
pub fn read_trace(path: &str) -> Result<Vec<TraceEvent>> {
    let body =
        std::fs::read_to_string(path).with_context(|| format!("reading trace file {path}"))?;
    Ok(body.lines().filter_map(parse_line).collect())
}

/// Render the full report for a trace file: a per-phase summary table
/// plus, when the trace contains `pkt.level` events, the per-level
/// breakdown (edges peeled, sub-levels, time) of Figs. 4–6.
pub fn render_trace_report(path: &str) -> Result<String> {
    let events = read_trace(path)?;
    anyhow::ensure!(!events.is_empty(), "trace {path} contains no span events");
    let mut out = String::new();

    // --- phase summary: aggregate by span name ---
    let mut phases: BTreeMap<&str, (u64, f64, f64)> = BTreeMap::new();
    for ev in &events {
        let slot = phases.entry(ev.name.as_str()).or_insert((0, 0.0, 0.0));
        slot.0 += 1;
        slot.1 += ev.dur_us;
        slot.2 = slot.2.max(ev.dur_us);
    }
    let mut t = Table::new(&["phase", "count", "total_s", "mean_s", "max_s"]);
    for (name, (count, total_us, max_us)) in &phases {
        t.row(vec![
            name.to_string(),
            count.to_string(),
            format!("{:.6}", total_us * 1e-6),
            format!("{:.6}", total_us * 1e-6 / *count as f64),
            format!("{:.6}", max_us * 1e-6),
        ]);
    }
    out.push_str("phase summary\n");
    out.push_str(&t.render());

    // --- per-level breakdown from pkt.level events ---
    // Aggregated by level label, so a trace holding several PKT runs
    // reports per-level totals across runs.
    let mut levels: BTreeMap<u64, (u64, u64, f64)> = BTreeMap::new();
    for ev in events.iter().filter(|e| e.name == "pkt.level") {
        let level: u64 = match ev.label("level").and_then(|v| v.parse().ok()) {
            Some(l) => l,
            None => continue,
        };
        let edges: u64 = ev.label("edges").and_then(|v| v.parse().ok()).unwrap_or(0);
        let subs: u64 = ev.label("sublevels").and_then(|v| v.parse().ok()).unwrap_or(0);
        let slot = levels.entry(level).or_insert((0, 0, 0.0));
        slot.0 += edges;
        slot.1 += subs;
        slot.2 += ev.dur_us;
    }
    if !levels.is_empty() {
        let total_level_us: f64 = levels.values().map(|v| v.2).sum();
        let mut cum_us = 0.0;
        let mut t = Table::new(&["level", "k", "edges", "sublevels", "time_s", "cdf_%"]);
        for (level, (edges, subs, dur_us)) in &levels {
            cum_us += dur_us;
            t.row(vec![
                level.to_string(),
                (level + 2).to_string(),
                edges.to_string(),
                subs.to_string(),
                format!("{:.6}", dur_us * 1e-6),
                format!("{:.1}", 100.0 * cum_us / total_level_us.max(1e-12)),
            ]);
        }
        out.push_str("\npkt levels\n");
        out.push_str(&t.render());
    }

    // --- totals: the same quantities PktStats reports ---
    let sum_us = |name: &str| -> f64 {
        events.iter().filter(|e| e.name == name).map(|e| e.dur_us).sum()
    };
    let support = sum_us("pkt.support") * 1e-6;
    let peel = sum_us("pkt.peel") * 1e-6;
    let scan = sum_us("pkt.scan") * 1e-6;
    let process = sum_us("pkt.process") * 1e-6;
    if support > 0.0 || peel > 0.0 {
        out.push_str(&format!(
            "\ntotals: support={support:.6}s scan={scan:.6}s process={process:.6}s \
             peel={peel:.6}s total={:.6}s\n",
            support + peel
        ));
    }
    Ok(out)
}

/// Extract the string value following `pat`, unescaping JSON escapes.
fn extract_string(line: &str, pat: &str) -> Option<String> {
    let start = line.find(pat)? + pat.len();
    let mut out = String::new();
    let mut chars = line[start..].chars();
    loop {
        match chars.next()? {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                'n' => out.push('\n'),
                'r' => out.push('\r'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                c => out.push(c),
            },
            c => out.push(c),
        }
    }
}

/// Extract the number following `pat` (digits, sign, dot, exponent).
fn extract_number(line: &str, pat: &str) -> Option<f64> {
    let start = line.find(pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse `"k":"v",...}` (cursor just past the opening brace).
fn parse_label_map(mut rest: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    loop {
        rest = rest.trim_start_matches(',');
        if rest.starts_with('}') || rest.is_empty() {
            return out;
        }
        let Some(key_end) = scan_string(rest) else { return out };
        let key = unescape(&rest[1..key_end]);
        rest = &rest[key_end + 1..];
        let Some(stripped) = rest.strip_prefix(':') else { return out };
        rest = stripped;
        let Some(val_end) = scan_string(rest) else { return out };
        let val = unescape(&rest[1..val_end]);
        rest = &rest[val_end + 1..];
        out.push((key, val));
    }
}

/// For input starting with `"`, return the byte index of the closing
/// unescaped quote.
fn scan_string(s: &str) -> Option<usize> {
    if !s.starts_with('"') {
        return None;
    }
    let bytes = s.as_bytes();
    let mut i = 1;
    while i < bytes.len() {
        match bytes[i] {
            b'"' => return Some(i),
            b'\\' => i += 2,
            _ => i += 1,
        }
    }
    None
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                if let Some(c) = u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    out.push(c);
                }
            }
            Some(c) => out.push(c),
            None => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_event() {
        let ev = parse_line("{\"name\":\"pkt.scan\",\"tid\":2,\"ts_us\":10.500,\"dur_us\":3.250}")
            .unwrap();
        assert_eq!(ev.name, "pkt.scan");
        assert_eq!(ev.tid, 2);
        assert!((ev.ts_us - 10.5).abs() < 1e-9);
        assert!((ev.dur_us - 3.25).abs() < 1e-9);
        assert!(ev.labels.is_empty());
    }

    #[test]
    fn parse_event_with_labels() {
        let ev = parse_line(
            "{\"name\":\"pkt.level\",\"tid\":0,\"ts_us\":1.000,\"dur_us\":2.000,\
             \"labels\":{\"level\":\"3\",\"edges\":\"1021\"}}",
        )
        .unwrap();
        assert_eq!(ev.label("level"), Some("3"));
        assert_eq!(ev.label("edges"), Some("1021"));
        assert_eq!(ev.label("missing"), None);
    }

    #[test]
    fn parse_roundtrips_escapes() {
        let ev = parse_line(
            "{\"name\":\"a\\\"b\",\"tid\":0,\"ts_us\":0.000,\"dur_us\":0.000,\
             \"labels\":{\"k\":\"x\\\\y\\nz\"}}",
        )
        .unwrap();
        assert_eq!(ev.name, "a\"b");
        assert_eq!(ev.label("k"), Some("x\\y\nz"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_line("").is_none());
        assert!(parse_line("not json").is_none());
        assert!(parse_line("{\"name\":\"x\"}").is_none());
    }

    #[test]
    fn report_renders_phase_and_level_tables() {
        let path = std::env::temp_dir().join("trussx_report_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            "{\"name\":\"pkt.support\",\"tid\":0,\"ts_us\":0.000,\"dur_us\":1000.000}\n\
             {\"name\":\"pkt.level\",\"tid\":0,\"ts_us\":1000.000,\"dur_us\":600.000,\
             \"labels\":{\"level\":\"0\",\"edges\":\"10\",\"sublevels\":\"2\"}}\n\
             {\"name\":\"pkt.level\",\"tid\":0,\"ts_us\":1600.000,\"dur_us\":400.000,\
             \"labels\":{\"level\":\"1\",\"edges\":\"4\",\"sublevels\":\"1\"}}\n\
             {\"name\":\"pkt.peel\",\"tid\":0,\"ts_us\":1000.000,\"dur_us\":1100.000}\n",
        )
        .unwrap();
        let report = render_trace_report(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(report.contains("phase summary"), "{report}");
        assert!(report.contains("pkt levels"), "{report}");
        // level 0 row: level=0, k=2, edges=10, sublevels=2, time=600µs, cdf=60%
        let levels_section = &report[report.find("pkt levels").unwrap()..];
        let row0: Vec<String> = levels_section
            .lines()
            .find(|l| l.starts_with('|') && l.contains("0.000600"))
            .unwrap_or_else(|| panic!("level-0 row missing: {report}"))
            .split('|')
            .map(|c| c.trim().to_string())
            .filter(|c| !c.is_empty())
            .collect();
        assert_eq!(row0, vec!["0", "2", "10", "2", "0.000600", "60.0"], "{report}");
        assert!(report.contains("totals: support=0.001000s"), "{report}");
        assert!(report.contains("total=0.002100s"), "{report}");
    }

    #[test]
    fn report_errors_on_missing_file() {
        assert!(render_trace_report("/nonexistent/trace.jsonl").is_err());
    }
}
