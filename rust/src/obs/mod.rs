//! `obs` — zero-dependency observability: metrics, phase spans, trace
//! sink, and Prometheus-style exposition.
//!
//! The paper's entire argument is measured behavior — phase breakdowns
//! (Figs. 4–5), per-level frontier sizes (Fig. 6), thread scaling — so
//! the decomposition kernels, the parallel runtime, and the coordinator
//! all record into one process-global [`Registry`]:
//!
//! - [`registry`] — atomic `Counter` / `Gauge` / `Histogram` cells with
//!   label support; handles are lock-free on the hot path.
//! - [`span`] — RAII phase spans (nestable, thread-ordinal tagged) that
//!   feed `phase_seconds{phase=...}` histograms.
//! - [`sink`] — optional JSONL trace-event stream (`TRUSSX_TRACE` env
//!   var or `--trace` flag), one event per span close.
//! - [`expo`] — Prometheus text exposition, served by the coordinator's
//!   `METRICS` verb and dumped by the bench harness.
//! - [`report`] — offline phase/level tables from a captured trace
//!   (`pallas report <trace.jsonl>`).

pub mod expo;
pub mod registry;
pub mod report;
pub mod sink;
pub mod span;

pub use registry::{global, Counter, Gauge, Histogram, Registry, Snapshot};
pub use span::{span, span_with, thread_ord, Span};
