//! Prometheus-style text exposition of a [`Registry`] snapshot.
//!
//! Output follows the text format version 0.0.4: one `# TYPE` line per
//! metric name, counters/gauges as plain samples, histograms expanded
//! into cumulative `_bucket{le="..."}` samples plus `_sum` and
//! `_count`. Label values are escaped per the spec (`\\`, `\"`, `\n`).

use crate::obs::registry::{MetricKey, Registry, Snapshot};
use std::fmt::Write;

/// Render the whole registry as Prometheus exposition text.
pub fn render(reg: &Registry) -> String {
    let snaps = reg.snapshot();
    let mut out = String::new();
    let mut last_name = "";
    for (key, snap) in &snaps {
        if key.name != last_name {
            let ty = match snap {
                Snapshot::Counter(_) => "counter",
                Snapshot::Gauge(_) => "gauge",
                Snapshot::Histogram { .. } => "histogram",
            };
            let _ = writeln!(out, "# TYPE {} {}", key.name, ty);
            last_name = &key.name;
        }
        match snap {
            Snapshot::Counter(v) => {
                let _ = writeln!(out, "{}{} {}", key.name, label_block(key, None), v);
            }
            Snapshot::Gauge(v) => {
                let _ = writeln!(out, "{}{} {}", key.name, label_block(key, None), v);
            }
            Snapshot::Histogram { bounds, buckets, sum } => {
                let mut cum = 0u64;
                for (i, b) in bounds.iter().enumerate() {
                    cum += buckets[i];
                    let _ = writeln!(
                        out,
                        "{}_bucket{} {}",
                        key.name,
                        label_block(key, Some(&format!("{b}"))),
                        cum
                    );
                }
                cum += buckets[bounds.len()];
                let _ = writeln!(
                    out,
                    "{}_bucket{} {}",
                    key.name,
                    label_block(key, Some("+Inf")),
                    cum
                );
                let _ = writeln!(out, "{}_sum{} {}", key.name, label_block(key, None), sum);
                let _ = writeln!(out, "{}_count{} {}", key.name, label_block(key, None), cum);
            }
        }
    }
    out
}

/// Render `{k="v",...}` for a key, optionally appending an `le` label;
/// empty string when there are no labels at all.
fn label_block(key: &MetricKey, le: Option<&str>) -> String {
    if key.labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in &key.labels {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{}=\"{}\"", k, escape_label_value(v));
    }
    if let Some(le) = le {
        if !first {
            out.push(',');
        }
        let _ = write!(out, "le=\"{le}\"");
    }
    out.push('}');
    out
}

/// Escape a label value per the Prometheus text format: backslash,
/// double quote, and newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_rules() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape_label_value("two\nlines"), "two\\nlines");
    }

    #[test]
    fn counter_and_gauge_lines() {
        let r = Registry::new();
        r.counter("requests_total", &[("verb", "DECOMP")]).add(7);
        r.gauge("inflight", &[]).set(2.0);
        let text = render(&r);
        assert!(text.contains("# TYPE requests_total counter"));
        assert!(text.contains("requests_total{verb=\"DECOMP\"} 7"));
        assert!(text.contains("# TYPE inflight gauge"));
        assert!(text.contains("inflight 2"));
    }

    #[test]
    fn type_line_once_per_name() {
        let r = Registry::new();
        r.counter("reqs", &[("verb", "A")]).inc();
        r.counter("reqs", &[("verb", "B")]).inc();
        let text = render(&r);
        assert_eq!(text.matches("# TYPE reqs counter").count(), 1);
    }

    #[test]
    fn histogram_cumulative_buckets() {
        let r = Registry::new();
        let h = r.histogram_with_buckets("lat", &[("p", "x")], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = render(&r);
        assert!(text.contains("# TYPE lat histogram"));
        assert!(text.contains("lat_bucket{p=\"x\",le=\"0.1\"} 2"));
        assert!(text.contains("lat_bucket{p=\"x\",le=\"1\"} 3"));
        assert!(text.contains("lat_bucket{p=\"x\",le=\"+Inf\"} 4"));
        assert!(text.contains("lat_count{p=\"x\"} 4"));
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("lat_sum"))
            .expect("sum line present");
        let sum: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum - 5.6).abs() < 1e-9);
    }

    #[test]
    fn escaped_label_value_renders() {
        let r = Registry::new();
        r.counter("c", &[("path", "a\"b")]).inc();
        let text = render(&r);
        assert!(text.contains("c{path=\"a\\\"b\"} 1"));
    }
}
