//! Process-global metric registry: atomic counters, gauges, and
//! histograms with label support. Hand-rolled on std-only primitives —
//! the offline registry carries no `prometheus`/`metrics` crates.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones of the registered cell: look one up once (or cache it in a
//! `OnceLock`) and update it lock-free from any thread. The registry
//! mutex is only taken at registration and snapshot time, never on the
//! metric hot path.

use std::collections::BTreeMap;
use crate::par::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Default duration buckets (seconds) for phase/latency histograms:
/// 1 µs … 60 s, roughly logarithmic, matching the dynamic range between
/// a single sub-level barrier and a full large-graph decomposition.
pub const DEFAULT_TIME_BUCKETS: &[f64] = &[
    1e-6, 1e-5, 1e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0,
];

/// Identity of a metric: name plus its sorted label set.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl MetricKey {
    fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|&(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        Self { name: name.to_string(), labels }
    }
}

/// Monotone counter.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    fn new() -> Self {
        Self { cell: Arc::new(AtomicU64::new(0)) }
    }

    #[inline]
    pub fn inc(&self) {
        self.cell.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64` (stored as bits in an atomic).
#[derive(Clone)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    fn new() -> Self {
        Self { bits: Arc::new(AtomicU64::new(0f64.to_bits())) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, v: f64) {
        atomic_add_f64(&self.bits, v);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

struct HistogramCore {
    /// Sorted upper bounds; bucket `i` counts observations in
    /// `(bounds[i-1], bounds[i]]`, plus one trailing `+Inf` bucket.
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram (Prometheus `le` semantics: bounds are
/// inclusive upper edges). Non-finite observations are dropped.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        assert!(bounds.iter().all(|b| b.is_finite()), "histogram bounds must be finite");
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Self {
            core: Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets,
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0f64.to_bits()),
            }),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.core.bounds.partition_point(|&b| b < v);
        self.core.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.core.count.fetch_add(1, Ordering::Relaxed);
        atomic_add_f64(&self.core.sum_bits, v);
    }

    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed))
    }

    /// Per-bucket (non-cumulative) counts; the last slot is `+Inf`.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn bounds(&self) -> &[f64] {
        &self.core.bounds
    }
}

fn atomic_add_f64(bits: &AtomicU64, v: f64) {
    let mut cur = bits.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match bits.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

#[derive(Clone)]
enum MetricCell {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

struct Inner {
    metrics: BTreeMap<MetricKey, MetricCell>,
    /// Every label set of one metric name shares one type.
    kinds: BTreeMap<String, Kind>,
}

/// A metric registry. Usually accessed through the process-global
/// [`global()`] instance; separate registries exist for tests.
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time value of one metric (see [`Registry::snapshot`]).
#[derive(Clone, Debug)]
pub enum Snapshot {
    Counter(u64),
    Gauge(f64),
    Histogram { bounds: Vec<f64>, buckets: Vec<u64>, sum: f64 },
}

impl Registry {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner { metrics: BTreeMap::new(), kinds: BTreeMap::new() }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn cell(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        kind: Kind,
        make: impl FnOnce() -> MetricCell,
    ) -> MetricCell {
        let key = MetricKey::new(name, labels);
        let mut inner = self.lock();
        let existing_kind = *inner.kinds.entry(key.name.clone()).or_insert(kind);
        assert!(
            existing_kind == kind,
            "metric '{name}' already registered as a {} (requested {})",
            existing_kind.name(),
            kind.name()
        );
        inner.metrics.entry(key).or_insert_with(make).clone()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        match self.cell(name, labels, Kind::Counter, || MetricCell::Counter(Counter::new())) {
            MetricCell::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.cell(name, labels, Kind::Gauge, || MetricCell::Gauge(Gauge::new())) {
            MetricCell::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    /// Get or create a histogram with [`DEFAULT_TIME_BUCKETS`].
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        self.histogram_with_buckets(name, labels, DEFAULT_TIME_BUCKETS)
    }

    /// Get or create a histogram with explicit bucket bounds. If the
    /// metric already exists its original bounds win.
    pub fn histogram_with_buckets(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histogram {
        match self.cell(name, labels, Kind::Histogram, || {
            MetricCell::Histogram(Histogram::new(bounds))
        }) {
            MetricCell::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    /// Consistent point-in-time snapshot of every metric, sorted by
    /// (name, labels) — the input to the Prometheus exposition.
    pub fn snapshot(&self) -> Vec<(MetricKey, Snapshot)> {
        let inner = self.lock();
        inner
            .metrics
            .iter()
            .map(|(k, cell)| {
                let snap = match cell {
                    MetricCell::Counter(c) => Snapshot::Counter(c.get()),
                    MetricCell::Gauge(g) => Snapshot::Gauge(g.get()),
                    MetricCell::Histogram(h) => Snapshot::Histogram {
                        bounds: h.bounds().to_vec(),
                        buckets: h.bucket_counts(),
                        sum: h.sum(),
                    },
                };
                (k.clone(), snap)
            })
            .collect()
    }
}

/// The process-global registry every subsystem records into.
pub fn global() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_shared_across_lookups() {
        let r = Registry::new();
        let a = r.counter("reqs", &[("verb", "X")]);
        a.inc();
        a.add(2);
        let b = r.counter("reqs", &[("verb", "X")]);
        assert_eq!(b.get(), 3);
        // different labels → different cell
        let c = r.counter("reqs", &[("verb", "Y")]);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn label_order_is_canonical() {
        let r = Registry::new();
        let a = r.counter("m", &[("a", "1"), ("b", "2")]);
        a.inc();
        let b = r.counter("m", &[("b", "2"), ("a", "1")]);
        assert_eq!(b.get(), 1);
    }

    #[test]
    fn gauge_set_get() {
        let r = Registry::new();
        let g = r.gauge("load", &[]);
        g.set(1.5);
        assert_eq!(g.get(), 1.5);
        g.add(0.25);
        assert_eq!(g.get(), 1.75);
    }

    #[test]
    fn histogram_bucketing_le_semantics() {
        let r = Registry::new();
        let h = r.histogram_with_buckets("h", &[], &[0.1, 1.0, 10.0]);
        h.observe(0.05); // ≤ 0.1
        h.observe(0.1); // ≤ 0.1 (inclusive upper edge)
        h.observe(0.5); // ≤ 1.0
        h.observe(10.0); // ≤ 10.0
        h.observe(11.0); // +Inf
        assert_eq!(h.bucket_counts(), vec![2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 21.65).abs() < 1e-9);
    }

    #[test]
    fn histogram_drops_non_finite() {
        let r = Registry::new();
        let h = r.histogram_with_buckets("h", &[], &[1.0]);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn histogram_concurrent_observes() {
        let r = Registry::new();
        let h = r.histogram_with_buckets("h", &[], &[0.5]);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        h.observe(if i % 2 == 0 { 0.25 } else { 0.75 });
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.bucket_counts(), vec![2000, 2000]);
        assert!((h.sum() - 2000.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn type_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("m", &[]);
        let _ = r.gauge("m", &[]);
    }

    #[test]
    fn snapshot_sorted_and_complete() {
        let r = Registry::new();
        r.counter("b_total", &[]).inc();
        r.gauge("a_gauge", &[]).set(2.0);
        let snaps = r.snapshot();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].0.name, "a_gauge");
        assert_eq!(snaps[1].0.name, "b_total");
        match &snaps[1].1 {
            Snapshot::Counter(1) => {}
            other => panic!("{other:?}"),
        }
    }
}
