//! JSONL trace-event sink. Disabled by default; enabled by the
//! `TRUSSX_TRACE=<path>` environment variable or the `--trace <path>`
//! CLI flag (which calls [`set_path`]). One event is appended per span
//! close:
//!
//! ```json
//! {"name":"pkt.scan","tid":0,"ts_us":1234.567,"dur_us":89.012,"labels":{"level":"3"}}
//! ```
//!
//! `ts_us` is microseconds since the process span epoch, `dur_us` the
//! span duration in microseconds; both carry nanosecond resolution in
//! their fractional part. Writes are line-atomic (one mutex-guarded
//! `writeln!` per event), so traces from parallel regions interleave
//! but never tear.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::{Mutex, MutexGuard, OnceLock};

fn cell() -> &'static Mutex<Option<BufWriter<File>>> {
    static SINK: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();
    SINK.get_or_init(|| {
        let init = match std::env::var("TRUSSX_TRACE") {
            Ok(path) if !path.is_empty() => File::create(&path).ok().map(BufWriter::new),
            _ => None,
        };
        Mutex::new(init)
    })
}

fn lock() -> MutexGuard<'static, Option<BufWriter<File>>> {
    cell().lock().unwrap_or_else(|e| e.into_inner())
}

/// Route trace events to `path` (truncating it). Replaces and flushes
/// any previously configured sink.
pub fn set_path(path: &str) -> std::io::Result<()> {
    let file = File::create(path)?;
    let mut guard = lock();
    if let Some(mut old) = guard.take() {
        let _ = old.flush();
    }
    *guard = Some(BufWriter::new(file));
    Ok(())
}

/// Flush and drop the sink; subsequent span closes emit nothing.
pub fn disable() {
    let mut guard = lock();
    if let Some(mut w) = guard.take() {
        let _ = w.flush();
    }
}

/// Flush buffered events to disk (sink stays active).
pub fn flush() {
    if let Some(w) = lock().as_mut() {
        let _ = w.flush();
    }
}

/// Whether a sink is currently attached.
pub fn enabled() -> bool {
    lock().is_some()
}

/// Append one span event. No-op when the sink is disabled.
pub fn emit(name: &str, tid: u64, ts_us: f64, dur_us: f64, labels: &[(String, String)]) {
    let mut guard = lock();
    let Some(w) = guard.as_mut() else { return };
    let mut line = String::with_capacity(96);
    line.push_str("{\"name\":\"");
    push_json_escaped(&mut line, name);
    line.push_str("\",\"tid\":");
    line.push_str(&tid.to_string());
    line.push_str(",\"ts_us\":");
    line.push_str(&format!("{ts_us:.3}"));
    line.push_str(",\"dur_us\":");
    line.push_str(&format!("{dur_us:.3}"));
    if !labels.is_empty() {
        line.push_str(",\"labels\":{");
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push('"');
            push_json_escaped(&mut line, k);
            line.push_str("\":\"");
            push_json_escaped(&mut line, v);
            line.push('"');
        }
        line.push('}');
    }
    line.push('}');
    let _ = writeln!(w, "{line}");
}

fn push_json_escaped(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global; serialize the tests that reconfigure it.
    static SINK_TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn emit_writes_jsonl_lines() {
        let _guard = SINK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let path = std::env::temp_dir().join("trussx_sink_test_emit.jsonl");
        let path = path.to_str().unwrap().to_string();
        set_path(&path).unwrap();
        assert!(enabled());
        emit("test.sink.a", 3, 10.0, 2.5, &[]);
        emit(
            "test.sink.b",
            0,
            12.5,
            1.0,
            &[("level".to_string(), "4".to_string())],
        );
        disable();
        assert!(!enabled());
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().filter(|l| l.contains("test.sink.")).collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            "{\"name\":\"test.sink.a\",\"tid\":3,\"ts_us\":10.000,\"dur_us\":2.500}"
        );
        assert_eq!(
            lines[1],
            "{\"name\":\"test.sink.b\",\"tid\":0,\"ts_us\":12.500,\"dur_us\":1.000,\"labels\":{\"level\":\"4\"}}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_escapes_json_specials() {
        let _guard = SINK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let path = std::env::temp_dir().join("trussx_sink_test_escape.jsonl");
        let path = path.to_str().unwrap().to_string();
        set_path(&path).unwrap();
        emit(
            "test.sink.esc",
            0,
            0.0,
            0.0,
            &[("k".to_string(), "a\"b\\c\nd".to_string())],
        );
        disable();
        let body = std::fs::read_to_string(&path).unwrap();
        let line = body.lines().find(|l| l.contains("test.sink.esc")).unwrap();
        assert!(line.contains("a\\\"b\\\\c\\nd"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_without_sink_is_noop() {
        let _guard = SINK_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        disable();
        emit("test.sink.noop", 0, 0.0, 0.0, &[]);
    }
}
