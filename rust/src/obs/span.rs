//! RAII phase spans: scoped wall-clock timers with a stable thread
//! ordinal and monotonic process-relative timestamps. Spans nest freely
//! (each is an independent measurement), feed the per-phase duration
//! histogram `phase_seconds{phase=...}` in the global registry, and —
//! when the trace sink is enabled — emit one JSONL event per close.
//!
//! Dynamic labels (`level=3`, `edges=1021`, ...) go to the trace event
//! only, never to the registry, so metric cardinality stays bounded by
//! the set of phase names.

use crate::obs::registry::{global, Histogram};
use crate::obs::sink;
use crate::par::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Instant the process first touched the span subsystem; all trace
/// timestamps are microseconds since this epoch.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

static NEXT_TID: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Stable small ordinal for the calling thread (assigned on first use,
/// in first-touch order — not the OS thread id).
pub fn thread_ord() -> u64 {
    TID.with(|t| *t)
}

/// An open phase span. Close with [`Span::close`] to get the elapsed
/// seconds; dropping it unclosed records the measurement too.
pub struct Span {
    name: &'static str,
    labels: Vec<(String, String)>,
    tid: u64,
    start: Instant,
    start_us: f64,
    hist: Histogram,
    done: bool,
}

/// Open a span for `name`.
pub fn span(name: &'static str) -> Span {
    span_with(name, &[])
}

/// Open a span carrying extra trace-only labels. More labels can be
/// attached later with [`Span::label`].
pub fn span_with(name: &'static str, labels: &[(&str, &str)]) -> Span {
    let ep = epoch();
    let start = Instant::now();
    Span {
        name,
        labels: labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect(),
        tid: thread_ord(),
        start,
        start_us: start.duration_since(ep).as_secs_f64() * 1e6,
        hist: global().histogram("phase_seconds", &[("phase", name)]),
        done: false,
    }
}

impl Span {
    /// Attach a trace-only label before the span closes.
    pub fn label(&mut self, key: &str, value: &str) {
        self.labels.push((key.to_string(), value.to_string()));
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Close the span, recording its duration; returns elapsed seconds.
    pub fn close(mut self) -> f64 {
        self.finish()
    }

    fn finish(&mut self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        if self.done {
            return secs;
        }
        self.done = true;
        self.hist.observe(secs);
        sink::emit(self.name, self.tid, self.start_us, secs * 1e6, &self.labels);
        secs
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.done {
            self.finish();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_returns_elapsed_and_records() {
        let before = global().histogram("phase_seconds", &[("phase", "test.span.close")]).count();
        let sp = span("test.span.close");
        std::thread::sleep(std::time::Duration::from_millis(1));
        let secs = sp.close();
        assert!(secs >= 0.001);
        let after = global().histogram("phase_seconds", &[("phase", "test.span.close")]).count();
        assert_eq!(after, before + 1);
    }

    #[test]
    fn drop_records_once() {
        let h = global().histogram("phase_seconds", &[("phase", "test.span.drop")]);
        let before = h.count();
        {
            let _sp = span("test.span.drop");
        }
        assert_eq!(h.count(), before + 1);
    }

    #[test]
    fn nested_spans_record_independently() {
        let outer_h = global().histogram("phase_seconds", &[("phase", "test.span.outer")]);
        let inner_h = global().histogram("phase_seconds", &[("phase", "test.span.inner")]);
        let (ob, ib) = (outer_h.count(), inner_h.count());
        let outer = span("test.span.outer");
        let inner = span("test.span.inner");
        let inner_secs = inner.close();
        let outer_secs = outer.close();
        assert!(outer_secs >= inner_secs);
        assert_eq!(outer_h.count(), ob + 1);
        assert_eq!(inner_h.count(), ib + 1);
    }

    #[test]
    fn thread_ordinals_are_distinct() {
        let here = thread_ord();
        let there = std::thread::spawn(thread_ord).join().unwrap();
        assert_ne!(here, there);
        assert_eq!(here, thread_ord(), "stable within a thread");
    }
}
