//! Triangle counting and edge-support computation.
//!
//! - [`count_triangles`] / [`count_triangles_par`] — oriented triangle
//!   counting (the `N⁺` canonical form u < v < w), the Table 2 baseline;
//! - [`support_am4`] — the paper's Alg. 3: parallel support computation
//!   with a thread-local marking array and three atomic adds per triangle;
//! - [`support_ros`] — Rossi's Alg. 2: edge-based support computation,
//!   Θ(Σ d(u)+d(v)) work, no orientation;
//! - [`support_naive`] — serial sorted-merge oracle used by tests.

use crate::graph::{EdgeGraph, Graph, Vertex};
use crate::par::cancel::{CancelToken, Cancelled};
use crate::par::{Counter, Pool, CHUNK_SUPPORT};
use crate::par::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

/// Serial oriented triangle count: Σ_u Σ_{v ∈ N⁺(u)} |N⁺(u) ∩ N⁺(v)|
/// by sorted merge. Exact, allocation-free.
pub fn count_triangles(g: &Graph) -> u64 {
    let mut total = 0u64;
    for u in 0..g.n() as Vertex {
        let nu = g.neighbors(u);
        let su = nu.partition_point(|&w| w <= u);
        let nu_plus = &nu[su..];
        for &v in nu_plus {
            let nv = g.neighbors(v);
            let sv = nv.partition_point(|&w| w <= v);
            total += merge_count(nu_plus, &nv[sv..]);
        }
    }
    total
}

#[inline]
fn merge_count(a: &[Vertex], b: &[Vertex]) -> u64 {
    let (mut i, mut j, mut c) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

/// Parallel oriented triangle counting with per-thread marking arrays —
/// exactly the AM4 loop structure (Alg. 3) minus the edge-id
/// bookkeeping and atomics, so its work is Θ(m + Σ_v d⁺(v)²) and
/// Table 2's ordering experiment measures what the paper measured.
pub fn count_triangles_par(g: &Graph, pool: &Pool) -> u64 {
    let _sp = crate::obs::span("triangle.count_par");
    let n = g.n();
    let total = AtomicU64::new(0);
    let counter = Counter::new();
    pool.region(|ctx| {
        // X[w] marks w ∈ N⁺(u) for the u being processed
        let mut x = vec![false; n];
        let mut local = 0u64;
        ctx.for_dynamic(&counter, n, CHUNK_SUPPORT, |ui| {
            let u = ui as Vertex;
            let nu = g.neighbors(u);
            let split = nu.partition_point(|&w| w <= u);
            let (nu_minus, nu_plus) = nu.split_at(split);
            if nu_minus.is_empty() || nu_plus.is_empty() {
                return;
            }
            for &w in nu_plus {
                x[w as usize] = true;
            }
            // canonical triangle v < u < w: v ∈ N⁻(u), w ∈ N⁺(v) ∩ N⁺(u)
            for &v in nu_minus {
                let nv = g.neighbors(v);
                for &w in nv.iter().rev() {
                    if w <= u {
                        break;
                    }
                    if x[w as usize] {
                        local += 1;
                    }
                }
            }
            for &w in nu_plus {
                x[w as usize] = false;
            }
        });
        total.fetch_add(local, Ordering::Relaxed);
    });
    total.into_inner()
}

/// The paper's Alg. 3 (AM4): parallel edge-support computation over the
/// truss-augmented representation. Returns `S` (one entry per edge id):
/// the number of triangles containing each edge.
///
/// For every vertex `u`, its `N⁺(u)` is marked in the thread-local `X`
/// with the adjacency slot (`j+1`, so 0 means unmarked). Each `v ∈ N⁻(u)`
/// is then intersected against the marks through `N⁺(v)`, discovering
/// each triangle exactly once in the canonical form `v < u < w`, and the
/// three member edges get one atomic increment each.
pub fn support_am4(eg: &EdgeGraph, pool: &Pool) -> Vec<AtomicU32> {
    match support_am4_with(eg, pool, &CancelToken::never()) {
        Ok(s) => s,
        // a never-token cannot stop the computation
        Err(c) => unreachable!("support_am4 cancelled without a token: {c}"),
    }
}

/// [`support_am4`] with cooperative cancellation: the token is polled at
/// every chunk boundary of the dynamic schedule (one vertex chunk ≈ the
/// paper's `CHUNK_SUPPORT = 10`), so an expired deadline stops the
/// enumeration within one chunk per thread instead of after Θ(Σ d⁺²)
/// work.
pub fn support_am4_with(
    eg: &EdgeGraph,
    pool: &Pool,
    token: &CancelToken,
) -> Result<Vec<AtomicU32>, Cancelled> {
    let _sp = crate::obs::span("triangle.support_am4");
    let n = eg.n();
    let m = eg.m();
    let g = &eg.g;
    let s: Vec<AtomicU32> = (0..m).map(|_| AtomicU32::new(0)).collect();
    let counter = Counter::new();
    // one thread observing the token latches `halt` so the other threads
    // pay a Relaxed load (not an Instant::now) per chunk
    let halt = AtomicBool::new(false);
    let stop = || {
        // ORDERING: Relaxed is enough — halt is a hint that only makes
        // threads stop claiming chunks; the region join publishes
        // everything that matters.
        if halt.load(Ordering::Relaxed) {
            return true;
        }
        if token.should_stop().is_some() {
            halt.store(true, Ordering::Relaxed);
            return true;
        }
        false
    };
    pool.region(|ctx| {
        // X[w] = slot+1 of w within u's adjacency, 0 if unmarked
        let mut x = vec![0usize; n];
        ctx.for_dynamic_until(&counter, n, CHUNK_SUPPORT, &stop, |ui| {
            let u = ui as Vertex;
            let (lo, hi) = (g.xadj[ui], g.xadj[ui + 1]);
            let eo_u = eg.eo[ui];
            // mark N⁺(u)
            for j in eo_u..hi {
                x[g.adj[j] as usize] = j + 1;
            }
            // for each v ∈ N⁻(u), scan N⁺(v) descending while w > u
            for j in lo..eo_u {
                let v = g.adj[j] as usize;
                let e_vu = eg.eid[j];
                for k in (eg.eo[v]..g.xadj[v + 1]).rev() {
                    let w = g.adj[k];
                    if w <= u {
                        break;
                    }
                    let xw = x[w as usize];
                    if xw == 0 {
                        continue;
                    }
                    let e_vw = eg.eid[k];
                    let e_uw = eg.eid[xw - 1];
                    s[e_vw as usize].fetch_add(1, Ordering::Relaxed);
                    s[e_vu as usize].fetch_add(1, Ordering::Relaxed);
                    s[e_uw as usize].fetch_add(1, Ordering::Relaxed);
                }
            }
            // unmark
            for j in eo_u..hi {
                x[g.adj[j] as usize] = 0;
            }
        });
    });
    if halt.load(Ordering::Relaxed) {
        return Err(token.stopped("triangle.support", format!("m={m} support incomplete")));
    }
    Ok(s)
}

/// Rossi's Alg. 2: edge-based parallel support computation. Each thread
/// processes whole edges, so `S[e]` needs no atomics; the cost is the
/// orientation-oblivious Θ(Σ_e d(u)+d(v)) work bound.
pub fn support_ros(eg: &EdgeGraph, pool: &Pool) -> Vec<u32> {
    let _sp = crate::obs::span("triangle.support_ros");
    let n = eg.n();
    let m = eg.m();
    let g = &eg.g;
    // S entries are disjointly owned per edge; use plain u32 behind
    // unsafe-free atomic stores via AtomicU32 (cheap, uncontended).
    let s: Vec<AtomicU32> = (0..m).map(|_| AtomicU32::new(0)).collect();
    let counter = Counter::new();
    pool.region(|ctx| {
        let mut x = vec![false; n];
        ctx.for_dynamic(&counter, m, CHUNK_SUPPORT, |e| {
            let (u, v) = eg.el[e];
            // canonical: scan the lower-degree endpoint's neighborhood
            let (a, b) = if g.degree(u) <= g.degree(v) { (u, v) } else { (v, u) };
            for &w in g.neighbors(a) {
                x[w as usize] = true;
            }
            let mut cnt = 0u32;
            for &w in g.neighbors(b) {
                if w != a && x[w as usize] {
                    cnt += 1;
                }
            }
            // a itself was marked; b ∈ N(a) so x[b] is set but w ranges
            // over N(b) which never contains b; exclude w == a above.
            s[e].store(cnt, Ordering::Relaxed);
            for &w in g.neighbors(a) {
                x[w as usize] = false;
            }
        });
    });
    s.into_iter().map(|a| a.into_inner()).collect()
}

/// Serial merge-based oracle: S[e] = |N(u) ∩ N(v)| for e = <u, v>.
pub fn support_naive(eg: &EdgeGraph) -> Vec<u32> {
    let g = &eg.g;
    eg.el
        .iter()
        .map(|&(u, v)| merge_count(g.neighbors(u), g.neighbors(v)) as u32)
        .collect()
}

/// Convert an atomic support vector into plain u32s (after a region).
pub fn into_plain(s: Vec<AtomicU32>) -> Vec<u32> {
    s.into_iter().map(|a| a.into_inner()).collect()
}

/// Triangle count from a support vector: Σ S[e] / 3.
pub fn triangles_from_support(s: &[u32]) -> u64 {
    s.iter().map(|&x| x as u64).sum::<u64>() / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::graph::GraphBuilder;
    use crate::util::forall;

    #[test]
    fn triangle_count_k4() {
        assert_eq!(count_triangles(&gen::complete(4)), 4);
        assert_eq!(count_triangles(&gen::complete(6)), 20);
    }

    #[test]
    fn triangle_count_triangle_free() {
        assert_eq!(count_triangles(&gen::ring(8)), 0);
        assert_eq!(count_triangles(&gen::star(9)), 0);
        assert_eq!(count_triangles(&gen::grid2d(4, 5)), 0);
    }

    #[test]
    fn parallel_count_matches_serial() {
        forall("tri-par-eq", 12, |rng| {
            let n = rng.range(2, 100);
            let g = gen::erdos_renyi(n, 0.15, rng.next_u64());
            let serial = count_triangles(&g);
            for t in [1, 2, 4] {
                assert_eq!(count_triangles_par(&g, &Pool::new(t)), serial);
            }
        });
    }

    #[test]
    fn am4_support_k4() {
        // every edge of K4 is in exactly 2 triangles
        let eg = EdgeGraph::new(gen::complete(4));
        let s = into_plain(support_am4(&eg, &Pool::new(1)));
        assert!(s.iter().all(|&x| x == 2), "{s:?}");
    }

    #[test]
    fn am4_matches_naive() {
        forall("am4-eq-naive", 16, |rng| {
            let n = rng.range(2, 80);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let oracle = support_naive(&eg);
            for t in [1, 2, 4] {
                let s = into_plain(support_am4(&eg, &Pool::new(t)));
                assert_eq!(s, oracle, "t={t}");
            }
        });
    }

    #[test]
    fn ros_matches_naive() {
        forall("ros-eq-naive", 16, |rng| {
            let n = rng.range(2, 80);
            let g = gen::erdos_renyi(n, 0.2, rng.next_u64());
            let eg = EdgeGraph::new(g);
            let oracle = support_naive(&eg);
            for t in [1, 4] {
                assert_eq!(support_ros(&eg, &Pool::new(t)), oracle, "t={t}");
            }
        });
    }

    #[test]
    fn support_consistent_with_triangle_count() {
        let g = gen::rmat(1024, 6_000, 0.57, 0.19, 0.19, 21);
        let tri = count_triangles(&g);
        let eg = EdgeGraph::new(g);
        let s = into_plain(support_am4(&eg, &Pool::new(2)));
        assert_eq!(triangles_from_support(&s), tri);
    }

    #[test]
    fn support_on_shared_edge() {
        // two triangles sharing edge (1,2): S[<1,2>] = 2, others 1
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
            .build();
        let eg = EdgeGraph::new(g);
        let s = support_naive(&eg);
        let e12 = eg.edge_id(1, 2).unwrap() as usize;
        assert_eq!(s[e12], 2);
        let e01 = eg.edge_id(0, 1).unwrap() as usize;
        assert_eq!(s[e01], 1);
    }

    #[test]
    fn support_cancellation_stops_early() {
        let eg = EdgeGraph::new(gen::erdos_renyi(400, 0.1, 7));
        // an already-expired deadline must stop before completion
        let token = CancelToken::with_timeout(Some(std::time::Duration::ZERO));
        let err = support_am4_with(&eg, &Pool::new(2), &token).unwrap_err();
        assert_eq!(err.at, "triangle.support");
        // an inert token yields the exact same result as the plain entry
        let ok = support_am4_with(&eg, &Pool::new(2), &CancelToken::never()).unwrap();
        assert_eq!(into_plain(ok), support_naive(&eg));
    }

    #[test]
    fn empty_and_tiny() {
        let eg = EdgeGraph::new(GraphBuilder::new().build());
        assert!(support_naive(&eg).is_empty());
        assert!(into_plain(support_am4(&eg, &Pool::new(2))).is_empty());
        let eg1 = EdgeGraph::new(GraphBuilder::new().edge(0, 1).build());
        assert_eq!(into_plain(support_am4(&eg1, &Pool::new(2))), vec![0]);
    }
}
