//! Differential check for batch-dynamic truss maintenance: the
//! maintained state of a [`crate::truss::DynamicTruss`] must equal what
//! a from-scratch run computes on the same graph.
//!
//! Two comparisons, both exact:
//!
//! - the maintained per-edge *support* against a serial triangle
//!   recount ([`check_support`] — incremental ±1 deltas drift silently
//!   if a shared triangle is double-claimed);
//! - the maintained per-edge *trussness* against a fresh PKT
//!   decomposition with the same [`PktConfig`] — this is the oracle
//!   that catches a wrong affected-region bound, a mis-pinned context
//!   edge, or a stale write-back.
//!
//! Like every other check this is opt-in (a full recompute per batch is
//! exactly the cost dynamic maintenance exists to avoid): it runs when
//! [`crate::validate::enabled`] holds, and always through
//! [`crate::truss::DynamicTruss::validate_maintained`].

use super::results::check_support;
use super::Report;
use crate::graph::EdgeGraph;
use crate::obs;
use crate::par::Pool;
use crate::truss::{pkt_config, PktConfig};

/// Check maintained `support` and `trussness` for `eg` against a
/// serial recount and a from-scratch decomposition.
pub fn check_dynamic(
    eg: &EdgeGraph,
    support: &[u32],
    trussness: &[u32],
    pool: &Pool,
    cfg: &PktConfig,
    rep: &mut Report,
) {
    let sp = obs::span("validate.dynamic");
    rep.checks_run += 1;
    check_support(eg, support, rep);
    if trussness.len() != eg.m() {
        rep.fail(
            "dynamic.trussness",
            "trussness.len".into(),
            format!("{} != m={}", trussness.len(), eg.m()),
        );
        sp.close();
        return;
    }
    let fresh = pkt_config(eg, pool, cfg);
    for (e, (&have, &want)) in trussness.iter().zip(fresh.trussness.iter()).enumerate() {
        if have != want {
            let (u, v) = eg.el[e];
            rep.fail(
                "dynamic.trussness",
                format!("edge[{e}]=<{u},{v}>"),
                format!("maintained {have} != recomputed {want}"),
            );
        }
    }
    sp.close();
}
