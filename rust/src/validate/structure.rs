//! Structural checks: CSR storage, the edge representation, and the
//! compaction remap.
//!
//! Unlike the `panic!`-on-corruption `Graph::validate` /
//! `EdgeGraph::validate` debug helpers, everything here is bounds-guarded
//! and *reports* through a [`Report`] — corrupt data must produce a
//! precise path, never a secondary panic. Checks return early once a
//! structural premise breaks (e.g. offset arrays of the wrong length)
//! because later invariants are meaningless on top of it.

use super::Report;
use crate::graph::{EdgeCompaction, EdgeGraph, EdgeId, Graph, Vertex};
use crate::obs;

/// CSR well-formedness: offset monotonicity, neighbor range, strictly
/// sorted rows (which also excludes duplicates), no self-loops, and
/// undirected symmetry.
pub fn check_graph(g: &Graph, rep: &mut Report) {
    let _sp = obs::span("validate.graph");
    rep.checks_run += 1;
    let n = g.n();
    if g.xadj.len() != n + 1 {
        rep.fail(
            "csr.offsets",
            "graph.xadj".into(),
            format!("length {} != n+1 = {}", g.xadj.len(), n + 1),
        );
        return;
    }
    if g.xadj[0] != 0 {
        rep.fail("csr.offsets", "graph.xadj[0]".into(), format!("{} != 0", g.xadj[0]));
        return;
    }
    for u in 0..n {
        if g.xadj[u] > g.xadj[u + 1] {
            rep.fail(
                "csr.offsets",
                format!("graph.xadj[{u}]"),
                format!("offsets decrease: {} > {}", g.xadj[u], g.xadj[u + 1]),
            );
            return;
        }
    }
    if g.xadj[n] != g.adj.len() {
        rep.fail(
            "csr.offsets",
            format!("graph.xadj[{n}]"),
            format!("{} != adj length {}", g.xadj[n], g.adj.len()),
        );
        return;
    }
    for u in 0..n {
        let row = &g.adj[g.xadj[u]..g.xadj[u + 1]];
        for (k, &v) in row.iter().enumerate() {
            if (v as usize) >= n {
                rep.fail(
                    "csr.range",
                    format!("graph.adj[{}] (row u={u})", g.xadj[u] + k),
                    format!("neighbor {v} >= n = {n}"),
                );
                return;
            }
            if v as usize == u {
                rep.fail(
                    "csr.selfloop",
                    format!("graph.adj[{}] (row u={u})", g.xadj[u] + k),
                    format!("self-loop on vertex {u}"),
                );
            }
        }
        for (k, w) in row.windows(2).enumerate() {
            if w[0] >= w[1] {
                rep.fail(
                    "csr.sorted",
                    format!("graph.adj row u={u} (positions {k},{})", k + 1),
                    format!("neighbors {} !< {}", w[0], w[1]),
                );
                break; // one report per row; the rest is noise
            }
        }
    }
    // symmetry: every arc (u, v) needs its reverse (v, u). Rows are
    // checked sorted above, so binary search is valid on clean rows; on
    // an unsorted row it may misreport, but the report is already red.
    for u in 0..n {
        for &v in g.neighbors(u as Vertex) {
            if g.neighbors(v).binary_search(&(u as Vertex)).is_err() {
                rep.fail(
                    "csr.symmetry",
                    format!("arc ({u},{v})"),
                    format!("reverse arc ({v},{u}) missing"),
                );
            }
        }
    }
}

/// Edge-representation invariants (the paper's Fig. 2 structure): `el`
/// strictly lexicographic with `u < v`, `eid` consistent with adjacency
/// and covering every id exactly twice, `eo` splitting each row at the
/// owner vertex.
pub fn check_edge_graph(eg: &EdgeGraph, rep: &mut Report) {
    let _sp = obs::span("validate.edge_graph");
    rep.checks_run += 1;
    let n = eg.n();
    let m = eg.m();
    if eg.el.len() != m || eg.eid.len() != eg.g.adj.len() || eg.eo.len() != n {
        rep.fail(
            "edge.lengths",
            "edge_graph".into(),
            format!(
                "el/eid/eo lengths ({}, {}, {}) inconsistent with (m={}, 2m={}, n={})",
                eg.el.len(),
                eg.eid.len(),
                eg.eo.len(),
                m,
                eg.g.adj.len(),
                n
            ),
        );
        return;
    }
    for (e, &(u, v)) in eg.el.iter().enumerate() {
        if u >= v || (v as usize) >= n {
            rep.fail(
                "edge.canonical",
                format!("el[{e}]=<{u},{v}>"),
                "endpoints must satisfy u < v < n".into(),
            );
        }
    }
    for (e, w) in eg.el.windows(2).enumerate() {
        if w[0] >= w[1] {
            rep.fail(
                "edge.lex_order",
                format!("el[{e}]..el[{}]", e + 1),
                format!("<{},{}> !< <{},{}>", w[0].0, w[0].1, w[1].0, w[1].1),
            );
        }
    }
    // eid ↔ adjacency consistency and 2-regular id cover
    let mut seen = vec![0u32; m];
    for u in 0..n {
        let (lo, hi) = (eg.g.xadj[u], eg.g.xadj[u + 1]);
        if eg.eo[u] < lo || eg.eo[u] > hi {
            rep.fail(
                "edge.eo_range",
                format!("eo[{u}]"),
                format!("{} outside row bounds [{lo}, {hi}]", eg.eo[u]),
            );
            continue;
        }
        for j in lo..hi {
            let v = eg.g.adj[j];
            let e = eg.eid[j] as usize;
            if e >= m {
                rep.fail(
                    "edge.eid_range",
                    format!("eid[{j}] (row u={u})"),
                    format!("edge id {e} >= m = {m}"),
                );
                continue;
            }
            seen[e] += 1;
            let canon = if (u as Vertex) < v { (u as Vertex, v) } else { (v, u as Vertex) };
            if eg.el[e] != canon {
                rep.fail(
                    "edge.eid_endpoints",
                    format!("eid[{j}] (row u={u})"),
                    format!("id {e} maps to el={:?}, expected <{},{}>", eg.el[e], canon.0, canon.1),
                );
            }
            let is_lower = j < eg.eo[u];
            if is_lower != ((v as usize) < u) {
                rep.fail(
                    "edge.eo_split",
                    format!("adj[{j}] (row u={u})"),
                    format!("neighbor {v} on the wrong side of eo[{u}]={}", eg.eo[u]),
                );
            }
        }
    }
    for (e, &c) in seen.iter().enumerate() {
        if c != 2 {
            let (u, v) = eg.el[e];
            rep.fail(
                "edge.eid_cover",
                format!("edge[{e}]=<{u},{v}>"),
                format!("id appears {c} times in eid, expected 2"),
            );
        }
    }
}

/// Compaction-remap invariants: `old_of_new` is a strictly increasing
/// enumeration of exactly the edges `alive` accepts (a bijection onto
/// the survivors), endpoints are preserved, and the rebuilt sub-graph is
/// itself a well-formed [`EdgeGraph`].
pub fn check_compaction<F>(old: &EdgeGraph, comp: &EdgeCompaction, alive: F, rep: &mut Report)
where
    F: Fn(EdgeId) -> bool,
{
    let _sp = obs::span("validate.compaction");
    rep.checks_run += 1;
    let old_m = old.m();
    if comp.eg.m() != comp.old_of_new.len() {
        rep.fail(
            "compaction.lengths",
            "old_of_new".into(),
            format!("new graph has m={} but map has {}", comp.eg.m(), comp.old_of_new.len()),
        );
        return;
    }
    for (i, w) in comp.old_of_new.windows(2).enumerate() {
        if w[0] >= w[1] {
            rep.fail(
                "compaction.monotone",
                format!("old_of_new[{i}..={}]", i + 1),
                format!("{} !< {} (lex order of ids breaks the ownership rule)", w[0], w[1]),
            );
        }
    }
    let mut mapped = vec![false; old_m];
    for (new, &o) in comp.old_of_new.iter().enumerate() {
        if (o as usize) >= old_m {
            rep.fail(
                "compaction.range",
                format!("old_of_new[{new}]"),
                format!("old id {o} >= old m = {old_m}"),
            );
            continue;
        }
        mapped[o as usize] = true;
        if comp.eg.el[new] != old.el[o as usize] {
            rep.fail(
                "compaction.endpoints",
                format!("old_of_new[{new}]={o}"),
                format!("endpoints {:?} != old {:?}", comp.eg.el[new], old.el[o as usize]),
            );
        }
    }
    // bijection onto the survivors: alive ⇔ mapped, both directions
    for e in 0..old_m {
        let a = alive(e as EdgeId);
        if a != mapped[e] {
            let (u, v) = old.el[e];
            rep.fail(
                "compaction.bijection",
                format!("edge[{e}]=<{u},{v}>"),
                if a {
                    "alive edge missing from the compacted graph".into()
                } else {
                    "dead edge resurrected in the compacted graph".into()
                },
            );
        }
    }
    check_edge_graph(&comp.eg, rep);
}
