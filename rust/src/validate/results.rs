//! Result checks: support arrays against a serial triangle recount, and
//! trussness output against its analytic bounds.

use super::Report;
use crate::graph::EdgeGraph;
use crate::obs;

/// Serial per-edge triangle recount (the oracle the parallel AM4 path is
/// checked against). Thin alias so callers and mutation tests name the
/// intent rather than the implementation.
pub fn recount_support(eg: &EdgeGraph) -> Vec<u32> {
    crate::triangle::support_naive(eg)
}

/// Compare a support array against a freshly recounted one.
pub fn check_support(eg: &EdgeGraph, support: &[u32], rep: &mut Report) {
    let _sp = obs::span("validate.support");
    rep.checks_run += 1;
    if support.len() != eg.m() {
        rep.fail(
            "support.length",
            "support".into(),
            format!("length {} != m = {}", support.len(), eg.m()),
        );
        return;
    }
    let fresh = recount_support(eg);
    for (e, (&got, &want)) in support.iter().zip(&fresh).enumerate() {
        if got != want {
            let (u, v) = eg.el[e];
            rep.fail(
                "support.recount",
                format!("edge[{e}]=<{u},{v}>"),
                format!("support {got} != recounted triangle count {want}"),
            );
        }
    }
}

/// Trussness output sanity against the decomposition's analytic bounds:
///
/// - floor: every edge belongs to its own 2-truss, so `t(e) ≥ 2`;
/// - support bound: peeling only lowers support, so
///   `t(e) ≤ sup(e) + 2` with `sup` the *initial* triangle count;
/// - k-core bound: every vertex of a k-truss lies in a (k−1)-core, so
///   `t(e) ≤ min(core(u), core(v)) + 1`.
///
/// These are one-sided (monotone) bounds, not a full definition check —
/// the `truss::verify_definition` oracle stays a test-only tool because
/// its `O(t_max · m^1.5)` cost is unfit for a production flag.
pub fn check_trussness(eg: &EdgeGraph, trussness: &[u32], rep: &mut Report) {
    let _sp = obs::span("validate.trussness");
    rep.checks_run += 1;
    if trussness.len() != eg.m() {
        rep.fail(
            "truss.length",
            "trussness".into(),
            format!("length {} != m = {}", trussness.len(), eg.m()),
        );
        return;
    }
    if eg.m() == 0 {
        return;
    }
    let sup = recount_support(eg);
    let core = crate::kcore::bz(&eg.g);
    for (e, &t) in trussness.iter().enumerate() {
        let (u, v) = eg.el[e];
        let path = || format!("edge[{e}]=<{u},{v}>");
        if t < 2 {
            rep.fail("truss.floor", path(), format!("trussness {t} < 2"));
            continue;
        }
        if u64::from(t) > u64::from(sup[e]) + 2 {
            rep.fail(
                "truss.support_bound",
                path(),
                format!("trussness {t} > initial support {} + 2", sup[e]),
            );
        }
        let cb = core[u as usize].min(core[v as usize]) + 1;
        if t > cb {
            rep.fail(
                "truss.kcore_bound",
                path(),
                format!("trussness {t} > min(core({u}), core({v})) + 1 = {cb}"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::par::Pool;
    use crate::triangle;
    use crate::truss;

    #[test]
    fn clean_pipeline_passes_all_checks() {
        let eg = EdgeGraph::new(gen::planted_partition(3, 10, 0.8, 0.05, 7));
        let pool = Pool::new(2);
        let mut rep = Report::new();
        super::super::check_graph(&eg.g, &mut rep);
        super::super::check_edge_graph(&eg, &mut rep);
        let s = triangle::into_plain(triangle::support_am4(&eg, &pool));
        check_support(&eg, &s, &mut rep);
        let res = truss::pkt(&eg, &pool);
        check_trussness(&eg, &res.trussness, &mut rep);
        assert!(rep.ok(), "{:?}", rep.violations);
        assert_eq!(rep.checks_run, 4);
    }

    #[test]
    fn empty_graph_passes() {
        let eg = EdgeGraph::new(crate::graph::GraphBuilder::new().build());
        let mut rep = Report::new();
        super::super::check_graph(&eg.g, &mut rep);
        super::super::check_edge_graph(&eg, &mut rep);
        check_support(&eg, &[], &mut rep);
        check_trussness(&eg, &[], &mut rep);
        assert!(rep.ok(), "{:?}", rep.violations);
    }

    #[test]
    fn support_length_mismatch_reported() {
        let eg = EdgeGraph::new(gen::complete(4));
        let mut rep = Report::new();
        check_support(&eg, &[0, 0], &mut rep);
        assert_eq!(rep.violations.len(), 1);
        assert_eq!(rep.violations[0].check, "support.length");
    }

    #[test]
    fn kcore_bound_catches_inflated_trussness() {
        // K5 plus a pendant: claim trussness 5 on the pendant edge —
        // its tail vertex has coreness 1, so the bound must fire
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((4, 5));
        let g = crate::graph::GraphBuilder::new().edges_vec(edges).build();
        let eg = EdgeGraph::new(g);
        let pool = Pool::new(1);
        let mut t = truss::pkt(&eg, &pool).trussness;
        let tail = eg.edge_id(4, 5).unwrap() as usize;
        t[tail] = 5;
        let mut rep = Report::new();
        check_trussness(&eg, &t, &mut rep);
        assert!(rep.violations.iter().any(|v| v.check == "truss.kcore_bound"), "{rep:?}");
        assert!(
            rep.violations.iter().any(|v| v.path.contains("<4,5>")),
            "path names the edge: {rep:?}"
        );
    }
}
