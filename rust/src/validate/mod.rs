//! Deep structural invariant checking for the decomposition pipeline.
//!
//! A silent data race or a broken rebuild in the peel corrupts truss
//! numbers without failing fast; the output merely *looks* plausible.
//! This module re-derives the invariants every stage depends on and
//! reports precise paths to anything that does not hold:
//!
//! - [`check_graph`] — CSR well-formedness: monotone offsets, strictly
//!   sorted rows (no duplicates), no self-loops, symmetry;
//! - [`check_edge_graph`] — the truss representation of Fig. 2: `el`
//!   strictly lexicographic with `u < v`, `eid` consistent with the
//!   adjacency and a 2-regular cover of the id space, `eo` splitting
//!   each row at its owner vertex;
//! - [`check_compaction`] — the old↔new edge-id remap of an
//!   active-graph rebuild is a strictly increasing bijection onto the
//!   surviving edges and preserves endpoints;
//! - [`check_support`] — a support array against a serial triangle
//!   recount;
//! - [`check_trussness`] — output sanity: trussness ≥ 2, bounded by
//!   initial support + 2 and by the k-core bound
//!   `min(core(u), core(v)) + 1`;
//! - [`check_dynamic`] — batch-dynamic maintenance differential: the
//!   maintained support and trussness of a
//!   [`crate::truss::DynamicTruss`] against a serial recount and a
//!   from-scratch decomposition.
//!
//! Validation is opt-in (it adds serial re-derivation work): per job via
//! `JobConfig::validate` / the `--validate` CLI flag / the server's
//! `validate=true` option, or process-wide via `TRUSSX_VALIDATE=1`.
//! While enabled, the PKT peel also validates every compaction rebuild
//! in place. Each check runs under a `validate.*` obs span, and every
//! violation increments the `validate_failures_total` counter.

mod dynamic;
mod results;
mod structure;

pub use dynamic::check_dynamic;
pub use results::{check_support, check_trussness, recount_support};
pub use structure::{check_compaction, check_edge_graph, check_graph};

use crate::par::sync::atomic::{AtomicUsize, Ordering};

/// Keep at most this many violations in a report; the rest only count
/// (one corrupt array can otherwise flood thousands of identical lines).
const MAX_STORED: usize = 32;

/// One failed invariant: which check, where, and what was observed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Check name, e.g. `csr.sorted` or `compaction.bijection`.
    pub check: &'static str,
    /// Path to the offending object, e.g. `graph.adj row u=17` or
    /// `edge[42]=<3,9>`.
    pub path: String,
    /// Observed-vs-expected explanation.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}: {}", self.check, self.path, self.detail)
    }
}

/// Accumulates the outcome of one validation pass.
#[derive(Debug, Default)]
pub struct Report {
    /// First [`MAX_STORED`] violations, in discovery order.
    pub violations: Vec<Violation>,
    /// Violations beyond the storage cap (still counted in the metric).
    pub dropped: usize,
    /// Top-level checks executed.
    pub checks_run: usize,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a violation (also bumps `validate_failures_total`).
    pub fn fail(&mut self, check: &'static str, path: String, detail: String) {
        crate::obs::global().counter("validate_failures_total", &[]).inc();
        if self.violations.len() < MAX_STORED {
            self.violations.push(Violation { check, path, detail });
        } else {
            self.dropped += 1;
        }
    }

    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.dropped == 0
    }

    /// All stored violations as one multi-line message, `None` if clean.
    pub fn error(&self) -> Option<String> {
        if self.ok() {
            return None;
        }
        let mut lines: Vec<String> = self.violations.iter().map(|v| v.to_string()).collect();
        if self.dropped > 0 {
            lines.push(format!("... and {} more violations", self.dropped));
        }
        Some(lines.join("\n"))
    }

    /// Abort with the violation list — the in-peel hooks use this, where
    /// returning an error is not an option.
    pub fn panic_if_failed(&self, context: &str) {
        if let Some(err) = self.error() {
            panic!("validation failed in {context}:\n{err}");
        }
    }
}

/// Live [`ScopedEnable`] guards (process-wide, so the peel's compaction
/// hook sees a job-level opt-in without threading config through it).
static SCOPED: AtomicUsize = AtomicUsize::new(0);

/// True if validation is on: a [`ScopedEnable`] guard is alive or the
/// `TRUSSX_VALIDATE` environment variable is truthy.
pub fn enabled() -> bool {
    SCOPED.load(Ordering::Relaxed) > 0 || env_enabled()
}

/// `TRUSSX_VALIDATE` alone (ignores scoped guards).
pub fn env_enabled() -> bool {
    matches!(
        std::env::var("TRUSSX_VALIDATE").ok().as_deref(),
        Some("1" | "true" | "on" | "yes")
    )
}

/// RAII guard turning validation on for its lifetime (nestable).
pub struct ScopedEnable(());

pub fn enable_scoped() -> ScopedEnable {
    SCOPED.fetch_add(1, Ordering::Relaxed);
    ScopedEnable(())
}

impl Drop for ScopedEnable {
    fn drop(&mut self) {
        SCOPED.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_collects_and_caps() {
        let mut rep = Report::new();
        assert!(rep.ok());
        assert_eq!(rep.error(), None);
        for i in 0..MAX_STORED + 5 {
            rep.fail("test.check", format!("item[{i}]"), "boom".into());
        }
        assert!(!rep.ok());
        assert_eq!(rep.violations.len(), MAX_STORED);
        assert_eq!(rep.dropped, 5);
        let err = rep.error().unwrap();
        assert!(err.contains("[test.check] item[0]: boom"), "{err}");
        assert!(err.contains("5 more violations"), "{err}");
    }

    #[test]
    fn violation_display() {
        let v = Violation {
            check: "csr.sorted",
            path: "graph.adj row u=3".into(),
            detail: "neighbors 7 !< 7".into(),
        };
        assert_eq!(v.to_string(), "[csr.sorted] graph.adj row u=3: neighbors 7 !< 7");
    }

    #[test]
    fn scoped_enable_nests() {
        // no env var in the test environment; rely on guards only
        if env_enabled() {
            return;
        }
        assert!(!enabled());
        let g1 = enable_scoped();
        assert!(enabled());
        let g2 = enable_scoped();
        drop(g1);
        assert!(enabled(), "still one guard alive");
        drop(g2);
        assert!(!enabled());
    }

    #[test]
    #[should_panic(expected = "validation failed in unit-test")]
    fn panic_if_failed_panics() {
        let mut rep = Report::new();
        rep.fail("x.y", "z".into(), "bad".into());
        rep.panic_if_failed("unit-test");
    }

    #[test]
    fn failures_metric_increments() {
        let c = crate::obs::global().counter("validate_failures_total", &[]);
        let before = c.get();
        let mut rep = Report::new();
        rep.fail("metric.check", "p".into(), "d".into());
        rep.fail("metric.check", "q".into(), "d".into());
        assert!(c.get() >= before + 2);
    }
}
