//! k-core decomposition substrate.
//!
//! Three algorithms, mirroring the paper's §2:
//! - [`bz`] — Batagelj–Zaversnik bucket peeling, O(n + m), serial. Used
//!   by the KCO preprocessing ordering.
//! - [`park`] — ParK/PKC-style level-synchronous parallel peeling
//!   (Dasari et al. [22], improved by the paper's authors as PKC [33]);
//!   the template PKT generalizes from vertices to edges.
//! - [`mpm`] — Montresor–De Pellegrini–Miorandi local h-index iteration
//!   [34]; synchronization-free but not work-efficient.

use crate::graph::{Graph, Vertex};
use crate::par::cancel::{CancelToken, Cancelled};
use crate::par::{AtomicVec, BatchWriter, Counter, Pool};
use crate::par::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, Ordering};

/// Serial BZ k-core: returns the coreness of every vertex.
pub fn bz(g: &Graph) -> Vec<u32> {
    let _sp = crate::obs::span("kcore.bz");
    let n = g.n();
    if n == 0 {
        return vec![];
    }
    let mut deg: Vec<u32> = (0..n).map(|u| g.degree(u as Vertex) as u32).collect();
    let maxd = *deg.iter().max().unwrap() as usize;

    // counting sort of vertices by degree
    let mut bin = vec![0usize; maxd + 2];
    for &d in &deg {
        bin[d as usize + 1] += 1;
    }
    for d in 0..=maxd {
        bin[d + 1] += bin[d];
    }
    let mut vert = vec![0 as Vertex; n]; // vertices in degree order
    let mut pos = vec![0usize; n]; // position of each vertex in vert
    {
        let mut cursor = bin.clone();
        for u in 0..n {
            let d = deg[u] as usize;
            pos[u] = cursor[d];
            vert[pos[u]] = u as Vertex;
            cursor[d] += 1;
        }
    }

    // peel in degree order; bin[d] = start of bucket d (shrinks as we go)
    for i in 0..n {
        let v = vert[i];
        let dv = deg[v as usize];
        for &u in g.neighbors(v) {
            let du = deg[u as usize];
            if du > dv {
                // move u to the front of its bucket, then shrink bucket
                let pu = pos[u as usize];
                let pw = bin[du as usize];
                let w = vert[pw];
                if u != w {
                    vert.swap(pu, pw);
                    pos[u as usize] = pw;
                    pos[w as usize] = pu;
                }
                bin[du as usize] += 1;
                deg[u as usize] -= 1;
            }
        }
    }
    deg
}

/// Parallel ParK-style k-core. Level-synchronous peeling with frontier
/// arrays; the direct vertex analogue of PKT's edge peeling.
pub fn park(g: &Graph, pool: &Pool) -> Vec<u32> {
    match park_with(g, pool, &CancelToken::never()) {
        Ok(core) => core,
        // a never-token cannot stop the peel
        Err(c) => unreachable!("park cancelled without a token: {c}"),
    }
}

/// [`park`] with cooperative cancellation, polled at level boundaries —
/// the natural checkpoint of the level-synchronous structure (tid 0
/// checks after finishing each level; the level in flight always runs
/// to completion, so the peel invariants hold when we unwind).
pub fn park_with(g: &Graph, pool: &Pool, token: &CancelToken) -> Result<Vec<u32>, Cancelled> {
    let _sp = crate::obs::span("kcore.park");
    let n = g.n();
    if n == 0 {
        return Ok(vec![]);
    }
    let deg: Vec<AtomicI64> =
        (0..n).map(|u| AtomicI64::new(g.degree(u as Vertex) as i64)).collect();
    let core: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let frontier_a: AtomicVec<Vertex> = AtomicVec::with_capacity(n);
    let frontier_b: AtomicVec<Vertex> = AtomicVec::with_capacity(n);
    let todo = AtomicI64::new(n as i64);
    let scan_counter = Counter::new();
    let proc_counter = Counter::new();
    let want_stop = AtomicBool::new(false);

    pool.region(|ctx| {
        let mut level: i64 = 0;
        // frontier flip: even sub-phase reads A writes B, odd reads B
        // writes A; every thread tracks `flip` identically.
        while todo.load(Ordering::Acquire) > 0 {
            // SCAN: static schedule over the degree array
            {
                let mut w = BatchWriter::new(&frontier_a);
                ctx.for_static(n, |u| {
                    if deg[u].load(Ordering::Relaxed) == level {
                        w.push(u as Vertex);
                    }
                });
            }
            ctx.barrier();
            let mut flip = false;
            loop {
                let (cur, nxt) = if !flip {
                    (&frontier_a, &frontier_b)
                } else {
                    (&frontier_b, &frontier_a)
                };
                let cur_len = cur.len();
                if cur_len == 0 {
                    break;
                }
                if ctx.tid == 0 {
                    todo.fetch_sub(cur_len as i64, Ordering::AcqRel);
                }
                // process current frontier (dynamic schedule)
                {
                    let cur_slice = cur.as_slice();
                    let mut w = BatchWriter::new(nxt);
                    ctx.for_dynamic(&proc_counter, cur_len, 16, |i| {
                        let v = cur_slice[i];
                        core[v as usize].store(level as u32, Ordering::Relaxed);
                        for &u in g.neighbors(v) {
                            if deg[u as usize].load(Ordering::Relaxed) > level {
                                let a = deg[u as usize].fetch_sub(1, Ordering::AcqRel);
                                if a == level + 1 {
                                    w.push(u);
                                }
                                if a <= level {
                                    // overshoot: another thread already
                                    // brought u to this level
                                    deg[u as usize].fetch_add(1, Ordering::AcqRel);
                                }
                            }
                        }
                    });
                }
                ctx.barrier();
                if ctx.tid == 0 {
                    cur.clear();
                    proc_counter.reset();
                    scan_counter.reset();
                }
                ctx.barrier();
                flip = !flip;
            }
            ctx.barrier();
            if ctx.tid == 0 {
                frontier_a.clear();
                frontier_b.clear();
                // level boundary: the cooperative cancellation checkpoint
                // (same tid-0 publish pattern as the compaction request
                // in the PKT stage loop)
                if token.should_stop().is_some() {
                    // ORDERING: Release pairs with the Acquire below;
                    // every thread must agree on the exit decision taken
                    // at this boundary.
                    want_stop.store(true, Ordering::Release);
                }
            }
            ctx.barrier();
            level += 1;
            if want_stop.load(Ordering::Acquire) {
                break;
            }
        }
    });

    if want_stop.load(Ordering::Acquire) && todo.load(Ordering::Acquire) > 0 {
        let remaining = todo.load(Ordering::Acquire).max(0);
        return Err(token.stopped("kcore.level", format!("remaining={remaining}/{n}")));
    }
    Ok(core.into_iter().map(|c| c.into_inner()).collect())
}

/// Maximum coreness (`c_max` in Table 1).
pub fn max_coreness(core: &[u32]) -> u32 {
    core.iter().copied().max().unwrap_or(0)
}

/// MPM local k-core (Montresor–De Pellegrini–Miorandi [34]): start from
/// degrees and repeatedly apply the h-index update ρ(v) ← H({ρ(u) : u ∈
/// N(v)}) until fixpoint. Not work-efficient (every edge touched each
/// round) but synchronization-free — the paper's §2 contrast case to
/// the level-synchronous ParK, mirrored at the truss level by
/// [`crate::truss::local`].
pub fn mpm(g: &Graph, pool: &Pool, max_rounds: u32) -> Vec<u32> {
    let _sp = crate::obs::span("kcore.mpm");
    let n = g.n();
    if n == 0 {
        return vec![];
    }
    let rho: Vec<AtomicU32> =
        (0..n).map(|u| AtomicU32::new(g.degree(u as Vertex) as u32)).collect();
    let rho_new: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let changed = crate::par::sync::atomic::AtomicBool::new(true);
    let counter = Counter::new();
    pool.region(|ctx| {
        let mut vals: Vec<u32> = Vec::new();
        let mut round = 0u32;
        loop {
            if !changed.load(Ordering::Acquire) || round >= max_rounds {
                break;
            }
            ctx.barrier();
            if ctx.tid == 0 {
                changed.store(false, Ordering::Release);
                counter.reset();
            }
            ctx.barrier();
            ctx.for_dynamic(&counter, n, 32, |u| {
                vals.clear();
                vals.extend(
                    g.neighbors(u as Vertex)
                        .iter()
                        .map(|&v| rho[v as usize].load(Ordering::Relaxed)),
                );
                // h-index of neighbor estimates
                vals.sort_unstable_by(|a, b| b.cmp(a));
                let mut h = 0u32;
                for (i, &v) in vals.iter().enumerate() {
                    if v as usize > i {
                        h = (i + 1) as u32;
                    } else {
                        break;
                    }
                }
                let old = rho[u].load(Ordering::Relaxed);
                let new = h.min(old);
                rho_new[u].store(new, Ordering::Relaxed);
                if new != old {
                    changed.store(true, Ordering::Release);
                }
            });
            ctx.barrier();
            ctx.for_static(n, |u| {
                rho[u].store(rho_new[u].load(Ordering::Relaxed), Ordering::Relaxed);
            });
            ctx.barrier();
            round += 1;
        }
    });
    rho.into_iter().map(|a| a.into_inner()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::forall;

    #[test]
    fn bz_complete_graph() {
        let g = gen::complete(6);
        let core = bz(&g);
        assert!(core.iter().all(|&c| c == 5));
    }

    #[test]
    fn bz_ring() {
        let g = gen::ring(10);
        assert!(bz(&g).iter().all(|&c| c == 2));
    }

    #[test]
    fn bz_star() {
        let g = gen::star(8);
        let core = bz(&g);
        assert!(core.iter().all(|&c| c == 1));
    }

    #[test]
    fn bz_paper_figure1_graph() {
        // Figure 1: all vertices have coreness 3. Two triangle-fans
        // sharing structure; reconstruct: the figure shows 8 vertices
        // where every vertex has coreness 3. Use two K4s sharing an edge.
        let g = crate::graph::GraphBuilder::new()
            .edges(&[
                (0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), // K4 a
                (2, 4), (3, 4), (4, 5), (2, 5), (3, 5), // K4 b on {2,3,4,5}
            ])
            .build();
        let core = bz(&g);
        assert!(core.iter().all(|&c| c == 3), "{core:?}");
    }

    #[test]
    fn bz_pendant_vertex() {
        // K5 + pendant: clique coreness 4, pendant coreness 1
        let mut edges = vec![];
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push((u, v));
            }
        }
        edges.push((0, 5));
        let g = crate::graph::GraphBuilder::new().edges_vec(edges).build();
        let core = bz(&g);
        assert_eq!(core[5], 1);
        assert_eq!(core[0], 4);
        assert_eq!(core[1], 4);
    }

    #[test]
    fn park_matches_bz() {
        forall("park-eq-bz", 16, |rng| {
            let n = rng.range(2, 120);
            let g = gen::erdos_renyi(n, 0.1, rng.next_u64());
            let serial = bz(&g);
            for t in [1, 2, 4] {
                let par = park(&g, &Pool::new(t));
                assert_eq!(serial, par, "n={n} t={t}");
            }
        });
    }

    #[test]
    fn park_matches_bz_on_suite_graph() {
        let g = gen::rmat(2048, 10_000, 0.57, 0.19, 0.19, 13);
        let serial = bz(&g);
        let par = park(&g, &Pool::new(4));
        assert_eq!(serial, par);
    }

    #[test]
    fn coreness_le_degree() {
        forall("core-le-deg", 12, |rng| {
            let n = rng.range(2, 80);
            let g = gen::erdos_renyi(n, 0.15, rng.next_u64());
            let core = bz(&g);
            for u in 0..n {
                assert!(core[u] as usize <= g.degree(u as Vertex));
            }
        });
    }

    #[test]
    fn empty_graph() {
        let g = crate::graph::Graph::from_csr(vec![0], vec![]);
        assert!(bz(&g).is_empty());
        assert!(park(&g, &Pool::new(2)).is_empty());
    }

    #[test]
    fn park_cancellation_unwinds_cleanly() {
        let g = gen::erdos_renyi(300, 0.05, 11);
        // expired deadline: the first level-boundary check fires while
        // vertices remain, and the error reports the partial progress
        let token = CancelToken::with_timeout(Some(std::time::Duration::ZERO));
        let err = park_with(&g, &Pool::new(2), &token).unwrap_err();
        assert_eq!(err.at, "kcore.level");
        assert!(err.partial.contains("remaining="), "{}", err.partial);
        // an inert token matches the serial oracle exactly
        let core = park_with(&g, &Pool::new(2), &CancelToken::never()).unwrap();
        assert_eq!(core, bz(&g));
    }

    #[test]
    fn isolated_vertices_core_zero() {
        let g = crate::graph::GraphBuilder::new().num_vertices(4).edge(0, 1).build();
        let core = bz(&g);
        assert_eq!(core, vec![1, 1, 0, 0]);
    }

    #[test]
    fn mpm_matches_bz() {
        forall("mpm-eq-bz", 14, |rng| {
            let n = rng.range(2, 100);
            let g = gen::erdos_renyi(n, 0.12, rng.next_u64());
            let serial = bz(&g);
            for t in [1, 3] {
                assert_eq!(mpm(&g, &Pool::new(t), 100_000), serial, "t={t}");
            }
        });
    }

    #[test]
    fn mpm_complete_and_star() {
        let g = gen::complete(7);
        assert!(mpm(&g, &Pool::new(2), 1000).iter().all(|&c| c == 6));
        let g = gen::star(9);
        assert!(mpm(&g, &Pool::new(2), 1000).iter().all(|&c| c == 1));
    }

    #[test]
    fn mpm_empty() {
        let g = crate::graph::Graph::from_csr(vec![0], vec![]);
        assert!(mpm(&g, &Pool::new(1), 10).is_empty());
    }
}
