//! `cargo bench` entry point (criterion is unavailable offline, so this
//! is a plain harness=false bench binary): regenerates every table and
//! figure of the paper via `trussx::bench` and writes them to
//! `bench_out/` as well as stdout.

use std::io::Write;

fn main() {
    // `cargo bench` passes --bench; accept an optional filter arg.
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let filter = args.first().map(|s| s.as_str());
    let threads = trussx::par::Pool::default_threads().max(4);
    let scale = std::env::var("TRUSSX_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    std::fs::create_dir_all("bench_out").ok();
    let mut failures = 0;
    for id in trussx::bench::ALL {
        if let Some(f) = filter {
            if !id.contains(f) {
                continue;
            }
        }
        eprintln!("=== bench {id} (scale={scale}, threads={threads}) ===");
        let t0 = std::time::Instant::now();
        match trussx::bench::run(id, scale, threads) {
            Ok(report) => {
                println!("{report}");
                println!("[{id} completed in {:.1}s]\n", t0.elapsed().as_secs_f64());
                let path = format!("bench_out/{id}.txt");
                if let Ok(mut f) = std::fs::File::create(&path) {
                    let _ = f.write_all(report.as_bytes());
                }
            }
            Err(e) => {
                eprintln!("bench {id} FAILED: {e:#}");
                failures += 1;
            }
        }
    }
    // dump the full metrics snapshot accumulated across the runs —
    // phase histograms, runtime load counters, etc.
    let expo = trussx::obs::expo::render(trussx::obs::global());
    if let Ok(mut f) = std::fs::File::create("bench_out/metrics.prom") {
        let _ = f.write_all(expo.as_bytes());
    }
    if failures > 0 {
        std::process::exit(1);
    }
}
