//! Quickstart: generate a graph, run the PKT truss decomposition, and
//! inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use trussx::coordinator::{run_job, GraphSpec, JobConfig};
use trussx::graph::EdgeGraph;
use trussx::par::Pool;
use trussx::truss;

fn main() -> anyhow::Result<()> {
    // 1. The high-level pipeline: spec string → report.
    let spec = GraphSpec::parse("rmat:n=4096,m=30000,seed=1")?;
    let report = run_job(&JobConfig::new(spec))?;
    println!("== pipeline API ==");
    println!("{}", report.summary());
    println!(
        "phase breakdown: support {:.1}% | scan {:.1}% | process {:.1}%",
        100.0 * report.stats.support_secs / report.stats.total_secs,
        100.0 * report.stats.scan_secs / report.stats.total_secs,
        100.0 * report.stats.process_secs / report.stats.total_secs,
    );
    println!("trussness histogram (k: edges):");
    for (k, &c) in report.histogram.iter().enumerate() {
        if c > 0 {
            println!("  {k:>3}: {c}");
        }
    }

    // 2. The low-level API: explicit graph → EdgeGraph → algorithm.
    println!("\n== low-level API ==");
    let g = trussx::gen::planted_partition(4, 16, 0.8, 0.01, 7);
    let eg = EdgeGraph::new(g);
    let pool = Pool::with_default_threads();
    let res = truss::pkt(&eg, &pool);
    let tmax = truss::max_trussness(&res.trussness);
    println!(
        "planted-partition 4x16: n={} m={} t_max={tmax}",
        eg.n(),
        eg.m()
    );
    // extract the maximal k-trusses at the deepest level
    let trusses = truss::ktruss_components(&eg, &res.trussness, tmax);
    println!("{}-trusses found: {}", tmax, trusses.len());
    for (i, t) in trusses.iter().enumerate() {
        println!("  truss {i}: {} edges", t.len());
    }
    Ok(())
}
