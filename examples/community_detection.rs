//! Community detection by k-truss peeling — one of the paper's
//! motivating applications (§1 cites k-truss as preprocessing for
//! community detection [9], [11], [14]).
//!
//! Generates a planted-partition graph with known ground-truth
//! communities, decomposes it, extracts the maximal k-trusses at
//! increasing k, and measures how well the trusses recover the planted
//! blocks (pairwise precision/recall against the ground truth).
//!
//! ```bash
//! cargo run --release --example community_detection
//! ```

use trussx::gen::{planted_community, planted_partition};
use trussx::graph::EdgeGraph;
use trussx::par::Pool;
use trussx::truss;

fn main() -> anyhow::Result<()> {
    let blocks = 8;
    let size = 24;
    let g = planted_partition(blocks, size, 0.65, 0.004, 2024);
    println!(
        "planted partition: {blocks} communities x {size} vertices, n={} m={}",
        g.n(),
        g.m()
    );

    let eg = EdgeGraph::new(g);
    let pool = Pool::with_default_threads();
    let res = truss::pkt(&eg, &pool);
    let tmax = truss::max_trussness(&res.trussness);
    println!("decomposed in {:.3}s, t_max={tmax}", res.stats.total_secs);

    println!(
        "\n{:>3} {:>9} {:>9} {:>10} {:>10} {:>8}",
        "k", "trusses", "edges", "precision", "recall", "F1"
    );
    let mut best = (0u32, 0.0f64);
    for k in 3..=tmax {
        let comps = truss::ktruss_components(&eg, &res.trussness, k);
        if comps.is_empty() {
            break;
        }
        // pairwise truss-cohabitation vs planted-community agreement,
        // over edges: an edge is "intra" if its endpoints share a block.
        let mut tp = 0u64; // edge kept in a truss, endpoints same block
        let mut fp = 0u64; // edge kept, endpoints different blocks
        let mut kept_edges = 0u64;
        for comp in &comps {
            for &(u, v) in comp {
                kept_edges += 1;
                if planted_community(u, size) == planted_community(v, size) {
                    tp += 1;
                } else {
                    fp += 1;
                }
            }
        }
        // total intra edges in the whole graph (recall denominator)
        let total_intra: u64 = eg
            .el
            .iter()
            .filter(|&&(u, v)| planted_community(u, size) == planted_community(v, size))
            .count() as u64;
        let precision = tp as f64 / (tp + fp).max(1) as f64;
        let recall = tp as f64 / total_intra.max(1) as f64;
        let f1 = 2.0 * precision * recall / (precision + recall).max(1e-12);
        println!(
            "{k:>3} {:>9} {kept_edges:>9} {precision:>10.4} {recall:>10.4} {f1:>8.4}",
            comps.len()
        );
        if f1 > best.1 {
            best = (k, f1);
        }
    }
    println!(
        "\nbest F1 = {:.4} at k = {} (expect near-perfect recovery once k \
         exceeds the inter-community noise level)",
        best.1, best.0
    );

    // sanity: at the best k, the number of trusses should match the
    // number of planted communities
    let comps = truss::ktruss_components(&eg, &res.trussness, best.0);
    println!("trusses at best k: {} (planted: {blocks})", comps.len());
    if comps.len() == blocks {
        println!("OK: k-truss peeling recovered the planted communities");
    }
    Ok(())
}
