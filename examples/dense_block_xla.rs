//! END-TO-END DRIVER: proves all three layers compose on a real
//! workload.
//!
//! 1. L3 native path: generate realistic graphs (RMAT social-network
//!    analogue + planted-partition web analogue), k-core order them, run
//!    the full PKT parallel decomposition.
//! 2. AOT path: load the `artifacts/*.hlo.txt` programs (lowered once
//!    from the L2 JAX model, which calls the L1 Pallas kernel) via the
//!    PJRT CPU client, and run the dense-block XLA decomposition of the
//!    same graphs.
//! 3. Assert the two paths agree **edge for edge**, then report
//!    throughput for both (GWeps, the paper's rate), and exercise the
//!    XLA support backend inside the PKT peel (support from XLA, peel
//!    native) as a third composition.
//!
//! Requires `make artifacts` (the Makefile dependency chain does this).
//!
//! ```bash
//! make artifacts && cargo run --release --example dense_block_xla
//! ```

use std::sync::atomic::AtomicI32;
use trussx::gen;
use trussx::graph::EdgeGraph;
use trussx::metrics::{gweps, time};
use trussx::order::{self, Ordering};
use trussx::par::Pool;
use trussx::runtime::{artifacts_dir, Runtime};
use trussx::truss::{self, dense::DenseBackend};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    println!("loading AOT artifacts from {}", dir.display());
    let mut rt = Runtime::cpu()?;
    let manifest = rt.load_manifest(&dir).map_err(|e| {
        anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first")
    })?;
    println!(
        "PJRT platform = {}, programs = {:?}, dense blocks = {:?}",
        rt.platform(),
        {
            let mut names = rt.names();
            names.sort();
            names
        },
        manifest.support_blocks()
    );

    let pool = Pool::with_default_threads();
    let workloads = vec![
        ("social (RMAT)", gen::rmat(256, 2200, 0.57, 0.19, 0.19, 99)),
        ("web (planted 8x24)", gen::planted_partition(8, 24, 0.7, 0.01, 98)),
        ("collab (WS)", gen::watts_strogatz(220, 5, 0.08, 97)),
    ];

    let mut all_agree = true;
    for (name, g0) in workloads {
        let (g, _) = order::reorder(&g0, Ordering::KCore);
        let eg = EdgeGraph::new(g);
        let wedges = eg.g.wedge_count();
        println!("\n== workload: {name} (n={}, m={}, wedges={wedges}) ==", eg.n(), eg.m());

        // --- L3 native PKT ---
        let (res, pkt_secs) = time(|| truss::pkt(&eg, &pool));
        println!(
            "  native PKT   : {:.4}s  ({:.4} GWeps, t_max={})",
            pkt_secs,
            gweps(wedges, pkt_secs),
            truss::max_trussness(&res.trussness)
        );

        // --- XLA dense path (L1 Pallas kernel inside the L2 model) ---
        let backend = DenseBackend::for_graph(&rt, &manifest, eg.n())?;
        let (xla_truss, xla_secs) = time(|| backend.decompose(&eg));
        let xla_truss = xla_truss?;
        println!(
            "  XLA dense    : {:.4}s  ({:.4} GWeps, block={})",
            xla_secs,
            gweps(wedges, xla_secs),
            backend.block
        );

        // --- composition 3: XLA support feeding the native PKT peel ---
        let (xla_support, sup_secs) = time(|| backend.support(&eg));
        let s: Vec<AtomicI32> = xla_support?
            .into_iter()
            .map(|x| AtomicI32::new(x as i32))
            .collect();
        let (hybrid, peel_secs) = time(|| truss::pkt_with_support(&eg, &pool, s));
        println!(
            "  hybrid       : {:.4}s  (XLA support {:.4}s + native peel {:.4}s)",
            sup_secs + peel_secs,
            sup_secs,
            peel_secs
        );

        let agree_xla = xla_truss == res.trussness;
        let agree_hybrid = hybrid.trussness == res.trussness;
        println!(
            "  agreement    : XLA=={} hybrid=={} over {} edges",
            agree_xla,
            agree_hybrid,
            eg.m()
        );
        all_agree &= agree_xla && agree_hybrid;
    }

    println!();
    if all_agree {
        println!("END-TO-END OK: L1 Pallas kernel -> L2 JAX model -> AOT HLO -> L3 Rust runtime all agree with the native PKT decomposition.");
        Ok(())
    } else {
        anyhow::bail!("layer disagreement detected");
    }
}
