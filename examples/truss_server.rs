//! Serving example: run the truss-analytics server and drive it with
//! concurrent clients, reporting request latency and throughput.
//!
//! ```bash
//! cargo run --release --example truss_server
//! ```

use std::time::Instant;
use trussx::coordinator::{serve, Client};

fn main() -> anyhow::Result<()> {
    let handle = serve("127.0.0.1:0")?;
    let addr = handle.addr;
    println!("server up on {addr}");

    // a mixed request stream: decompositions of varying size + hists
    let requests_per_client = 8;
    let clients = 4;
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let mut lat = Vec::new();
                let mut client = Client::connect(addr)?;
                for r in 0..requests_per_client {
                    let req = match r % 4 {
                        0 => format!("DECOMP rmat:n=1024,m=6000,seed={c}{r} algo=pkt threads=1"),
                        1 => format!("DECOMP er:n=800,p=0.01,seed={c}{r} algo=ros threads=1"),
                        2 => format!(
                            "HIST pp:blocks=4,size=14,pin=0.8,pout=0.01,seed={c}{r}"
                        ),
                        _ => format!("DECOMP ba:n=600,k=4,seed={c}{r} algo=local threads=1"),
                    };
                    let t = Instant::now();
                    let reply = client.request(&req)?;
                    anyhow::ensure!(reply.starts_with("OK "), "bad reply: {reply}");
                    lat.push(t.elapsed().as_secs_f64());
                }
                Ok(lat)
            })
        })
        .collect();

    let mut latencies: Vec<f64> = Vec::new();
    for w in workers {
        latencies.extend(w.join().expect("client thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = latencies.len();
    let pct = |p: f64| latencies[((total as f64 * p) as usize).min(total - 1)];
    println!("\n== load test: {clients} concurrent clients x {requests_per_client} requests ==");
    println!("requests     : {total}");
    println!("wall time    : {wall:.3}s");
    println!("throughput   : {:.1} req/s", total as f64 / wall);
    println!("latency p50  : {:.4}s", pct(0.50));
    println!("latency p90  : {:.4}s", pct(0.90));
    println!("latency p99  : {:.4}s", pct(0.99));
    println!("server jobs  : {}", handle.jobs_served());

    assert_eq!(handle.jobs_served() as usize, total);
    handle.shutdown();
    println!("server shut down cleanly");
    Ok(())
}
