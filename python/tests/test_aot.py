"""AOT pipeline tests: lowering produces loadable HLO text with the
expected entry computation and parameter shapes."""

import os
import subprocess
import sys

import pytest

from compile import aot


class TestLowering:
    @pytest.mark.parametrize("block", [16, 64])
    def test_support_lowers_to_hlo_text(self, block):
        text = aot.lower_support(block)
        assert "HloModule" in text
        assert f"f32[{block},{block}]" in text

    def test_peel_has_two_params(self, block=16):
        text = aot.lower_peel(block)
        assert "HloModule" in text
        # scalar threshold parameter present
        assert "f32[]" in text

    def test_local_lowers(self, block=16):
        text = aot.lower_local(block)
        assert "HloModule" in text

    def test_hlo_is_plain_ops_no_custom_call(self):
        # interpret=True must lower to plain HLO the CPU client can run —
        # a Mosaic custom-call would be unloadable (see DESIGN.md)
        for text in (aot.lower_support(16), aot.lower_local(16)):
            assert "custom-call" not in text.lower() or "mosaic" not in text.lower()

    def test_text_not_proto(self):
        # HLO text is ASCII and starts with the module header — guards
        # against accidentally switching to .serialize() (64-bit-id protos
        # that xla_extension 0.5.1 rejects)
        text = aot.lower_support(16)
        assert text.lstrip().startswith("HloModule")
        assert text.isascii()


class TestCliEndToEnd:
    def test_writes_artifacts_and_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        env = dict(os.environ)
        proc = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--blocks", "16"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert proc.returncode == 0, proc.stderr
        names = sorted(p.name for p in out.iterdir())
        assert names == [
            "local_16.hlo.txt",
            "manifest.txt",
            "peel_16.hlo.txt",
            "peelfix_16.hlo.txt",
            "support_16.hlo.txt",
        ]
        manifest = (out / "manifest.txt").read_text()
        assert "support_16\tsupport_16.hlo.txt" in manifest


class TestPeelfixLowering:
    def test_peelfix_lowers_with_while_loop(self):
        text = aot.lower_peelfix(16)
        assert "HloModule" in text
        assert "while" in text.lower(), "in-device fixpoint must lower to an HLO while loop"
