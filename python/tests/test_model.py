"""L2 model tests: peel fixpoints, decomposition agreement, padding."""

import numpy as np
import pytest

from compile import model
from compile.kernels.ref import support_ref, truss_decompose_ref
from tests.test_kernel import random_adjacency


def decompose_via_peel_model(a: np.ndarray, block: int) -> np.ndarray:
    """Drive model.peel_model the way the Rust coordinator does: iterate
    per k until the adjacency stops changing, label dropped edges."""
    n = a.shape[0]
    truss = np.zeros((n, n), dtype=np.int64)
    truss[a > 0] = 2
    cur = a.astype(np.float32)
    k = 2
    while cur.sum() > 0:
        while True:
            new, _s = model.peel_model(cur, np.float32(k - 1), block=block)
            new = np.asarray(new)
            dropped = (cur > 0) & (new == 0)
            if not dropped.any():
                break
            truss[dropped] = k
            cur = new
        k += 1
        assert k <= n + 3, "peel failed to converge"
    return truss


class TestPeelModel:
    @pytest.mark.parametrize("n,block", [(32, 16), (64, 64)])
    def test_matches_reference_decomposition(self, n, block):
        a = random_adjacency(n, 0.3, seed=n)
        got = decompose_via_peel_model(a, block)
        want = truss_decompose_ref(a)
        np.testing.assert_array_equal(got, want)

    def test_outputs_support_alongside(self):
        a = random_adjacency(32, 0.4, seed=5)
        new, s = model.peel_model(a, np.float32(0.0), block=16)
        np.testing.assert_allclose(np.asarray(s), np.asarray(support_ref(a)), atol=0)
        np.testing.assert_allclose(np.asarray(new), a, atol=0)  # thresh 0 keeps all

    def test_planted_blocks_decompose_to_cliques(self):
        # two disjoint K8s: every edge has trussness 8
        n = 16
        a = np.zeros((n, n), dtype=np.float32)
        for base in (0, 8):
            for i in range(8):
                for j in range(8):
                    if i != j:
                        a[base + i, base + j] = 1
        truss = decompose_via_peel_model(a, 16)
        assert (truss[a > 0] == 8).all()


class TestSupportModel:
    def test_tuple_arity(self):
        a = random_adjacency(16, 0.3, seed=2)
        out = model.support_model(a, block=16)
        assert isinstance(out, tuple) and len(out) == 1

    def test_matches_ref(self):
        a = random_adjacency(64, 0.25, seed=9)
        (s,) = model.support_model(a, block=32)
        np.testing.assert_allclose(np.asarray(s), np.asarray(support_ref(a)), atol=0)


class TestLocalModel:
    def test_round_is_ref_round(self):
        from compile.kernels.ref import local_step_ref

        a = random_adjacency(32, 0.35, seed=4)
        rho = np.asarray(support_ref(a))
        (out,) = model.local_model(a, rho, block=16)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(local_step_ref(a, rho)), atol=0
        )


class TestPadding:
    def test_pad_adjacency(self):
        a = np.ones((10, 10), dtype=np.float32)
        p = np.asarray(model.pad_adjacency(a, 16))
        assert p.shape == (16, 16)
        assert p[:10, :10].sum() == 100
        assert p[10:, :].sum() == 0

    def test_pad_noop_when_aligned(self):
        a = np.ones((16, 16), dtype=np.float32)
        p = np.asarray(model.pad_adjacency(a, 16))
        assert p.shape == (16, 16)

    def test_padded_support_equals_unpadded(self):
        a = random_adjacency(20, 0.4, seed=6)
        p = np.asarray(model.pad_adjacency(a, 32))
        (s,) = model.support_model(p, block=32)
        s = np.asarray(s)[:20, :20]
        np.testing.assert_allclose(s, np.asarray(support_ref(a)), atol=0)


class TestPeelConverge:
    def test_fixpoint_matches_iterated_peel(self):
        import jax.numpy as jnp
        from compile.kernels.ref import peel_ref

        a = random_adjacency(32, 0.3, seed=13)
        for thresh in (1.0, 2.0, 3.0):
            cur = jnp.asarray(a)
            for _ in range(100):
                new = peel_ref(cur, thresh)
                if bool((new == cur).all()):
                    break
                cur = new
            got, iters = model.peel_converge_model(a, np.float32(thresh), block=16)
            np.testing.assert_allclose(np.asarray(got), np.asarray(cur), atol=0)
            assert float(iters) >= 1.0

    def test_converge_on_stable_input_is_one_round(self):
        n = 16
        a = (np.ones((n, n)) - np.eye(n)).astype(np.float32)  # K16
        got, iters = model.peel_converge_model(a, np.float32(1.0), block=16)
        np.testing.assert_allclose(np.asarray(got), a, atol=0)
        assert float(iters) == 1.0
