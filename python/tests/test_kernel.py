"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, densities and block sizes; every case asserts
exact agreement (the kernels are integer-valued float math, so
assert_allclose with zero tolerance is appropriate).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import local_step, support
from compile.kernels.ref import local_step_ref, peel_ref, support_ref
from compile.kernels.support_matmul import mxu_utilization_estimate, vmem_bytes


def random_adjacency(n: int, density: float, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    upper = rng.rand(n, n) < density
    a = np.triu(upper, 1)
    a = (a | a.T).astype(np.float32)
    return a


# ---------------------------------------------------------------- support


class TestSupportKernel:
    @pytest.mark.parametrize("n,block", [(16, 16), (32, 16), (64, 64), (128, 64), (128, 128)])
    def test_matches_ref_shapes(self, n, block):
        a = random_adjacency(n, 0.3, seed=n + block)
        got = np.asarray(support(a, block=block))
        want = np.asarray(support_ref(a))
        np.testing.assert_allclose(got, want, atol=0)

    def test_complete_graph(self):
        n = 32
        a = (np.ones((n, n)) - np.eye(n)).astype(np.float32)
        s = np.asarray(support(a, block=16))
        # every edge of K_n is in n-2 triangles
        off = ~np.eye(n, dtype=bool)
        assert (s[off] == n - 2).all()
        assert (np.diagonal(s) == 0).all()

    def test_triangle_free(self):
        # ring graph: no triangles
        n = 32
        a = np.zeros((n, n), dtype=np.float32)
        for i in range(n):
            a[i, (i + 1) % n] = a[(i + 1) % n, i] = 1
        s = np.asarray(support(a, block=16))
        assert (s == 0).all()

    def test_empty(self):
        a = np.zeros((64, 64), dtype=np.float32)
        assert (np.asarray(support(a, block=64)) == 0).all()

    def test_symmetry_preserved(self):
        a = random_adjacency(64, 0.4, seed=7)
        s = np.asarray(support(a, block=32))
        np.testing.assert_allclose(s, s.T, atol=0)

    @settings(max_examples=25, deadline=None)
    @given(
        n_blocks=st.integers(1, 3),
        block=st.sampled_from([8, 16, 32]),
        density=st.floats(0.0, 0.8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_blocks, block, density, seed):
        n = n_blocks * block
        a = random_adjacency(n, density, seed)
        got = np.asarray(support(a, block=block))
        want = np.asarray(support_ref(a))
        np.testing.assert_allclose(got, want, atol=0)

    def test_rejects_non_divisible(self):
        a = random_adjacency(24, 0.3, seed=1)
        with pytest.raises(AssertionError):
            support(a, block=16)


# ---------------------------------------------------------------- local step


class TestLocalStepKernel:
    @pytest.mark.parametrize("n,block", [(16, 16), (32, 16), (64, 32)])
    def test_matches_ref(self, n, block):
        a = random_adjacency(n, 0.35, seed=n * 7 + block)
        rho = np.asarray(support_ref(a))
        got = np.asarray(local_step(a, rho, block=block))
        want = np.asarray(local_step_ref(a, rho))
        np.testing.assert_allclose(got, want, atol=0)

    def test_fixpoint_of_complete_graph(self):
        # K_n: rho = n-2 everywhere is already the fixpoint
        n = 16
        a = (np.ones((n, n)) - np.eye(n)).astype(np.float32)
        rho = np.asarray(support_ref(a))
        out = np.asarray(local_step(a, rho, block=16))
        np.testing.assert_allclose(out, rho, atol=0)

    def test_monotone_non_increasing(self):
        a = random_adjacency(32, 0.4, seed=3)
        rho = np.asarray(support_ref(a))
        out = np.asarray(local_step(a, rho, block=16))
        assert (out <= rho + 1e-6).all()
        assert (out >= 0).all()

    @settings(max_examples=15, deadline=None)
    @given(
        block=st.sampled_from([8, 16]),
        n_blocks=st.integers(1, 3),
        density=st.floats(0.0, 0.7),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, block, n_blocks, density, seed):
        n = block * n_blocks
        a = random_adjacency(n, density, seed)
        rho = np.asarray(support_ref(a))
        got = np.asarray(local_step(a, rho, block=block))
        want = np.asarray(local_step_ref(a, rho))
        np.testing.assert_allclose(got, want, atol=0)

    def test_iterated_convergence_matches_peeling(self):
        # iterate the local step to fixpoint; rho+2 must equal the
        # trussness from the reference peeling decomposition
        from compile.kernels.ref import truss_decompose_ref

        a = random_adjacency(32, 0.35, seed=11)
        rho = np.asarray(support_ref(a))
        for _ in range(200):
            new = np.asarray(local_step(a, rho, block=16))
            if np.array_equal(new, rho):
                break
            rho = new
        truss = truss_decompose_ref(a)
        edges = a > 0
        np.testing.assert_allclose(rho[edges] + 2, truss[edges], atol=0)


# ---------------------------------------------------------------- peel ref


class TestPeelRef:
    def test_peel_drops_low_support(self):
        # bowtie: two triangles sharing a vertex; all edges support 1
        a = np.zeros((8, 8), dtype=np.float32)
        for u, v in [(0, 1), (1, 2), (0, 2), (2, 3), (3, 4), (2, 4)]:
            a[u, v] = a[v, u] = 1
        out = np.asarray(peel_ref(a, 2.0))
        assert out.sum() == 0  # no edge has support >= 2

    def test_peel_keeps_dense_core(self):
        n = 16
        a = (np.ones((n, n)) - np.eye(n)).astype(np.float32)
        out = np.asarray(peel_ref(a, float(n - 2)))
        np.testing.assert_allclose(out, a, atol=0)


# ---------------------------------------------------------------- perf model


class TestPerfModel:
    def test_vmem_footprint_within_budget(self):
        # the AOT block sizes must fit VMEM with wide margin
        for block in (64, 128, 256):
            assert vmem_bytes(block) < 16 * 2**20 / 4, f"block {block}"

    def test_mxu_estimate_monotone_and_bounded(self):
        es = [mxu_utilization_estimate(b) for b in (64, 128, 256)]
        assert all(0.0 < e <= 1.0 for e in es)
        # 128-aligned blocks fully occupy the systolic array
        assert es[1] > es[0]
        assert es[1] > 0.95
