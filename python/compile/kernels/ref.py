"""Pure-jnp / numpy oracles for the Pallas kernels and the L2 model.

These are the correctness ground truth: simple, obviously-right
formulations with no tiling, checked against the kernels by
python/tests (pytest + hypothesis).
"""

import jax.numpy as jnp
import numpy as np


def support_ref(a):
    """S = (A @ A) ⊙ A — dense edge support, no tiling."""
    return (a @ a) * a


def peel_ref(a, thresh):
    """One peel step: drop edges with support < thresh."""
    s = support_ref(a)
    return a * (s >= thresh).astype(a.dtype)


def local_step_ref(a, rho):
    """Decrement local update (see kernels/hindex.py), dense reference.

    cnt[u,v] = Σ_w a[u,w]·a[w,v]·[ρ[u,w] ≥ ρ[u,v]]·[ρ[w,v] ≥ ρ[u,v]]
    ρ'[u,v]  = ρ[u,v] if cnt ≥ ρ[u,v] else max(ρ[u,v]−1, 0), masked to A.
    """
    a = jnp.asarray(a)
    rho = jnp.asarray(rho)
    ge_uw = (rho[:, :, None] >= rho[:, None, :]).astype(a.dtype)  # [u, w, v]
    ge_wv = (rho[None, :, :] >= rho[:, None, :]).astype(a.dtype)  # [u, w, v]
    term = a[:, :, None] * ge_uw * a[None, :, :] * ge_wv
    cnt = jnp.sum(term, axis=1)
    dec = jnp.maximum(rho - 1.0, 0.0)
    return jnp.where(cnt >= rho, rho, dec) * a


def truss_decompose_ref(adj):
    """Reference dense truss decomposition by repeated peeling (numpy).

    ``adj``: symmetric 0/1 numpy array, zero diagonal. Returns an int
    matrix T where T[u, v] = trussness of edge <u, v> (0 on non-edges).
    """
    a = np.array(adj, dtype=np.float64)
    n = a.shape[0]
    truss = np.zeros((n, n), dtype=np.int64)
    truss[a > 0] = 2
    k = 2
    while a.sum() > 0:
        while True:
            s = (a @ a) * a
            drop = (a > 0) & (s < k - 1)
            if not drop.any():
                break
            truss[drop] = k
            a[drop] = 0.0
        k += 1
        if k > n + 2:  # safety valve; trussness is bounded by n
            break
    return truss
