"""L1 Pallas kernel: one dense local truss update step.

The local algorithm's dense analogue (paper refs [19], [34]): every edge
(u, v) holds an estimate ρ[u, v] (initialized to its support); one round
counts the triangles through (u, v) whose two other edges both have
estimates ≥ ρ[u, v]:

    C[u, v] = Σ_w A[u, w]·A[w, v]·[ρ[u, w] ≥ ρ[u, v]]·[ρ[w, v] ≥ ρ[u, v]]

and applies the *decrement* update

    ρ'[u, v] = ρ[u, v]        if C[u, v] ≥ ρ[u, v]
               ρ[u, v] − 1    otherwise            (masked to edges).

Starting from ρ⁰ = S (an upper bound on trussness−2), the estimates
decrease monotonically by at most 1 per round and stop exactly when
every edge satisfies the k-class condition — i.e. at the greatest
fixpoint ≤ S, which is trussness−2. (A full h-index update converges in
fewer rounds but cannot be accumulated tile-by-tile across the k grid
dimension; the decrement form keeps the kernel a pure masked
contraction. Convergence is bounded by max S rounds.)

Kernel structure mirrors support_matmul: (i, j) output tiles with the
output resident across the inner k dimension; the thresholded operands
are built per k step (VPU compare/select feeding the contraction).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _local_step_kernel(a_ik_ref, a_kj_ref, rho_ik_ref, rho_kj_ref,
                       rho_ij_ref, mask_ref, out_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a_ik = a_ik_ref[...]      # (bt, bk)
    a_kj = a_kj_ref[...]      # (bk, bt)
    rho_ik = rho_ik_ref[...]  # (bt, bk)
    rho_kj = rho_kj_ref[...]  # (bk, bt)
    rho_ij = rho_ij_ref[...]  # (bt, bt)
    # ge_ik[u, w, v] = [rho_ik[u, w] >= rho_ij[u, v]]
    ge_ik = (rho_ik[:, :, None] >= rho_ij[:, None, :]).astype(jnp.float32)
    # ge_kj[u, w, v] = [rho_kj[w, v] >= rho_ij[u, v]]
    ge_kj = (rho_kj[None, :, :] >= rho_ij[:, None, :]).astype(jnp.float32)
    # C[u, v] += Σ_w a·ge·a·ge
    term = (a_ik[:, :, None] * ge_ik) * (a_kj[None, :, :] * ge_kj)
    out_ref[...] += jnp.sum(term, axis=1)

    @pl.when(k == n_k - 1)
    def _epilogue():
        rho = rho_ij_ref[...]
        cnt = out_ref[...]
        dec = jnp.maximum(rho - 1.0, 0.0)
        out_ref[...] = jnp.where(cnt >= rho, rho, dec) * mask_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def local_step(a, rho, block: int = 64):
    """One local-update round: returns the updated ρ (f32[n, n]).

    ``a``: f32[n, n] symmetric 0/1 adjacency, zero diagonal;
    ``rho``: f32[n, n] current estimates (0 on non-edges).
    """
    n = a.shape[0]
    assert a.shape == (n, n) and rho.shape == (n, n)
    bt = min(block, n)
    assert n % bt == 0, f"n={n} not divisible by block={bt}"
    n_b = n // bt
    grid = (n_b, n_b, n_b)
    spec_ik = pl.BlockSpec((bt, bt), lambda i, j, k: (i, k))
    spec_kj = pl.BlockSpec((bt, bt), lambda i, j, k: (k, j))
    spec_ij = pl.BlockSpec((bt, bt), lambda i, j, k: (i, j))
    return pl.pallas_call(
        functools.partial(_local_step_kernel, n_k=n_b),
        grid=grid,
        in_specs=[spec_ik, spec_kj, spec_ik, spec_kj, spec_ij, spec_ij],
        out_specs=spec_ij,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, a, rho, rho, rho, a)
