"""L1: Pallas kernels for the dense truss-support hot spot."""

from .hindex import local_step
from .support_matmul import support

__all__ = ["support", "local_step"]
