"""L1 Pallas kernel: dense edge-support via tiled masked matmul.

The Graphulo-style linear-algebra formulation of truss support (paper
ref [20]): for a dense 0/1 adjacency matrix ``A``, the per-edge triangle
count is ``S = (A @ A) * A`` — entry (u, v) counts common neighbors of u
and v, masked to actual edges.

TPU mapping (DESIGN.md §Hardware-Adaptation): the contraction ``A @ A``
targets the MXU; the grid iterates (i, j) output tiles with an inner k
dimension accumulating into the resident output tile (its BlockSpec
index map ignores k, so the tile stays in VMEM across the k loop); the
elementwise ``⊙ A`` mask fuses into the epilogue of the last k step.
Tiles are ``(BT, BT)`` f32 blocks sized for VMEM (default 128 → 64 KiB
per tile, 4 tiles resident ≈ 256 KiB ≪ 16 MiB).

Everything here runs with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the Rust runtime loads (see python/compile/aot.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _support_kernel(a_ik_ref, a_kj_ref, mask_ref, out_ref, *, n_k: int):
    """One (i, j, k) grid step: out += A[i,k] @ A[k,j]; mask on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ik_ref[...], a_kj_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        # fuse the ⊙A mask into the final k step
        out_ref[...] = out_ref[...] * mask_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def support(a, block: int = 128):
    """Dense edge support ``S = (A @ A) * A`` as a Pallas tiled kernel.

    ``a``: f32[n, n] symmetric 0/1 adjacency with zero diagonal; n must
    be divisible by ``block`` (pad upstream). Returns f32[n, n] with
    S[u, v] = number of triangles containing edge <u, v>.
    """
    n = a.shape[0]
    assert a.shape == (n, n), "adjacency must be square"
    bt = min(block, n)
    assert n % bt == 0, f"n={n} not divisible by block={bt}"
    n_b = n // bt
    grid = (n_b, n_b, n_b)
    return pl.pallas_call(
        functools.partial(_support_kernel, n_k=n_b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bt), lambda i, j, k: (i, k)),  # A[i, k]
            pl.BlockSpec((bt, bt), lambda i, j, k: (k, j)),  # A[k, j]
            pl.BlockSpec((bt, bt), lambda i, j, k: (i, j)),  # mask A[i, j]
        ],
        out_specs=pl.BlockSpec((bt, bt), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, a, a)


def vmem_bytes(block: int) -> int:
    """Estimated VMEM footprint of one grid step (3 input tiles + the
    resident output tile, f32). Used by DESIGN.md §Perf for the TPU
    estimate — must stay well under ~16 MiB."""
    return 4 * block * block * 4


def mxu_utilization_estimate(block: int) -> float:
    """Fraction of MXU peak the kernel's matmuls can reach, estimated as
    the ratio of tile matmul FLOPs to total tile FLOPs (matmul plus the
    mask epilogue), scaled by MXU geometry fit (the 128×128 systolic
    array is fully occupied when block is a multiple of 128)."""
    matmul_flops = 2 * block**3
    epilogue_flops = 2 * block**2  # mask multiply + store
    geometry = 1.0 if block % 128 == 0 else block / ((block // 128 + 1) * 128)
    return geometry * matmul_flops / (matmul_flops + epilogue_flops)
