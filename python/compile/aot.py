"""AOT lowering: jit the L2 models and dump HLO **text** artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Usage:  python -m compile.aot [--out-dir ../artifacts] [--blocks 64,128,256]

Writes, per block size B:
    support_B.hlo.txt   (A f32[B,B])            -> (S,)
    peel_B.hlo.txt      (A f32[B,B], thresh f32) -> (A', S)
    local_B.hlo.txt     (A f32[B,B], rho f32[B,B]) -> (rho',)
plus manifest.txt mapping names to files (read by rust/src/runtime).
Python runs ONCE at build time; the Rust binary is then self-contained.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_BLOCKS = (64, 128, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side unwraps a tuple regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_support(block: int) -> str:
    spec = jax.ShapeDtypeStruct((block, block), jnp.float32)
    fn = functools.partial(model.support_model, block=block)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_peel(block: int) -> str:
    spec = jax.ShapeDtypeStruct((block, block), jnp.float32)
    thresh = jax.ShapeDtypeStruct((), jnp.float32)
    fn = functools.partial(model.peel_model, block=block)
    return to_hlo_text(jax.jit(fn).lower(spec, thresh))


def lower_peelfix(block: int) -> str:
    spec = jax.ShapeDtypeStruct((block, block), jnp.float32)
    thresh = jax.ShapeDtypeStruct((), jnp.float32)
    fn = functools.partial(model.peel_converge_model, block=block)
    return to_hlo_text(jax.jit(fn).lower(spec, thresh))


def lower_local(block: int) -> str:
    spec = jax.ShapeDtypeStruct((block, block), jnp.float32)
    # the 3-D compare/select in the local kernel is heavy at 256; cap
    # its tile at 64 (see kernels/hindex.py docstring)
    fn = functools.partial(model.local_model, block=min(block, 64))
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--blocks",
        default=",".join(str(b) for b in DEFAULT_BLOCKS),
        help="comma-separated dense block sizes",
    )
    args = ap.parse_args()
    blocks = [int(b) for b in args.blocks.split(",") if b]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for b in blocks:
        for name, lower in (
            (f"support_{b}", lower_support),
            (f"peel_{b}", lower_peel),
            (f"peelfix_{b}", lower_peelfix),
            (f"local_{b}", lower_local),
        ):
            text = lower(b)
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest.append((name, fname))
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# trussx AOT artifact manifest: name<TAB>file\n")
        for name, fname in manifest:
            f.write(f"{name}\t{fname}\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')} ({len(manifest)} entries)")


if __name__ == "__main__":
    main()
