"""L2: the JAX compute graph — dense truss model over the L1 kernels.

Three jitted entry points, each lowered to HLO text by aot.py:

- ``support_model(A)``        → (S,)        one support computation
- ``peel_model(A, thresh)``   → (A', S)     one peel step (support + drop)
- ``local_model(A, rho)``     → (rho',)     one local-update round

The Rust coordinator iterates ``peel_model`` to a fixpoint per k (see
rust/src/truss/dense.rs) — the control loop lives in Rust, the dense
compute lives here, and the hot inner product lives in the L1 Pallas
kernel that both models call.
"""

import jax.numpy as jnp

from .kernels import local_step, support


def support_model(a, *, block: int = 128):
    """Edge support of every edge of the dense adjacency ``a``."""
    return (support(a, block=block),)


def peel_model(a, thresh, *, block: int = 128):
    """One peel step: recompute support, zero edges below ``thresh``.

    Returns (new adjacency, support) — the support output lets callers
    inspect the pre-peel state without a second XLA call.
    """
    s = support(a, block=block)
    keep = (s >= thresh).astype(a.dtype)
    a_new = a * keep
    # keep the result symmetric under float edge cases: A is symmetric
    # and S is symmetric, so a_new already is; assert via cheap identity
    return (a_new, s)


def peel_converge_model(a, thresh, *, block: int = 128):
    """Iterate the peel step **in-device** until it stops removing edges
    (`jax.lax.while_loop`), returning (stable adjacency, rounds as f32).

    One XLA execution replaces the per-iteration PJRT round trips the
    Rust driver would otherwise make — the L2 perf optimization recorded
    in EXPERIMENTS.md §Perf (the outer per-k loop stays in Rust, where
    the trussness labeling lives).
    """
    import jax

    def cond(state):
        _a, changed, _i = state
        return changed

    def body(state):
        a_cur, _, i = state
        s = support(a_cur, block=block)
        a_new = a_cur * (s >= thresh).astype(a_cur.dtype)
        changed = jnp.any(a_new != a_cur)
        return (a_new, changed, i + 1.0)

    a_out, _, iters = jax.lax.while_loop(
        cond, body, (a, jnp.bool_(True), jnp.float32(0.0))
    )
    return (a_out, iters)


def local_model(a, rho, *, block: int = 64):
    """One decrement-local-update round over estimates ``rho``."""
    return (local_step(a, rho, block=block),)


def pad_adjacency(a, block: int):
    """Pad a dense adjacency to the next multiple of ``block`` (helper
    for tests; the Rust side pads before building literals)."""
    n = a.shape[0]
    pad = (-n) % block
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, pad)))
